"""Online autotuner: close the loop from live meters to the plan
(ROADMAP item 3 — the controller half).

The loop, between training iterations::

    run K measured iterations
      -> machine_from_snapshot(eng.metrics_snapshot())   # live rates
      -> lp_search.solve_config under the live machine   # per candidate
      -> eng.apply_plan_config(...)                      # hot swap

``AutotuneController`` owns a measurement WINDOW: it resets the
engine's traffic meters / lookahead stats / span ring at each window
boundary, counts ``post_step()`` calls, and at every ``interval``-th
step reduces the window's ``metrics_snapshot()`` to a DECISION —
``hold`` / ``retune`` / ``blocked`` / ``cooldown`` — appended to
``eng.autotune_log`` (which ``metrics_snapshot()`` then embeds under
the additive ``"autotune"`` key) and mirrored as a tracer instant.

Measured-rate semantics (the post-fix contract this controller is
built on): a route's live bandwidth is ``trace.routes[r]["rate_bps"]
= bytes / busy_wall_s``, where ``busy_wall_s`` is the UNION of the
chunk-span intervals across the P concurrent path-channel threads —
see ``Tracer.summary`` / ``perfmodel.machine_from_snapshot``. The
pre-fix per-channel ``busy_s`` sum read ~1/P of a striped device's
aggregate rate, which would make this controller systematically
under-provision every plan it solved.

Why each guard exists:

* **reconcile gate** — before trusting the model to rank candidate
  plans, ``obs.reconcile``'s predicted-vs-measured ``route_seconds``
  table must agree within ``error_gate`` on the CURRENT plan: a model
  that cannot explain the plan it is watching has no business picking
  the next one (decision ``blocked``).
* **hysteresis** — a retune costs a quiesce-and-recompile and risks
  thrash under meter noise; the best candidate's predicted iteration
  time must beat the current plan's by ``hysteresis`` (decision
  ``hold`` otherwise).
* **cooldown / max_retunes** — bounded retune frequency: after a
  swap the next ``cooldown`` windows only re-measure (decision
  ``cooldown``), and ``max_retunes`` caps the total.

Trajectory neutrality: the candidate axes are the knobs proven
bitwise-invariant (``prefetch_depth``, ``act_policy``,
``path_policy`` — chunk placement moves bytes between paths, never
changes what any tensor holds) plus — explicit opt-in via
``wave_sizes`` — the wave axis, which is exact w.r.t. a fresh engine
compiled with the new W from the same state (the plan-swap satellite
pin) but regroups the cross-wave f32 fold. A retune therefore never
changes what the model learns, only when its bytes move.

Each decision also records the per-path steering signal
(``IOEngine.least_loaded_path`` / ``path_imbalance`` — MLP-Offload's
multi-path idle-level rule as live feedback). With ``path_policies``
configured the signal is no longer merely advisory: the snapshot's
per-path achieved rates flow into ``machine_from_snapshot``, the LP
prices "static" (``P x min(rate)``) against "backlog"/"weighted"
(``sum(rates)``) via ``machine_for_path_policy``, and a retune
actuates ``IOEngine.set_path_policy`` — closing the steering gap on
heterogeneous or degraded path sets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.lp_search import solve_config
from repro.core.perfmodel import MachineParams, machine_from_snapshot
from repro.offload.engine import engine_workload

__all__ = ["AutotuneConfig", "AutotuneController", "route_seconds_error"]


def route_seconds_error(predicted: Dict[str, float],
                        measured: Dict[str, float],
                        floor_s: float = 0.0) -> float:
    """Worst relative disagreement between the model's predicted
    route-seconds and the measured wall-clock envelope, over the
    routes BOTH sides observed — ``obs.reconcile``'s error signal
    reduced to the controller's scalar gate. Routes where both sides
    are under ``floor_s`` are ignored (micro-transfers measure mostly
    overhead). 0.0 when nothing was co-observed."""
    errs = []
    for route, p in predicted.items():
        m = measured.get(route)
        if m is None:
            continue
        hi = max(float(p), float(m))
        if hi <= floor_s or hi <= 0.0:
            continue
        errs.append(abs(float(p) - float(m)) / hi)
    return max(errs, default=0.0)


@dataclasses.dataclass
class AutotuneConfig:
    """Controller knobs. The candidate axes default to "current value
    only" — an axis only joins the search space when given explicitly,
    so the default controller can never leave the bitwise-invariant
    knob subclass (``wave_sizes`` is the opt-in exception documented
    in the module header)."""
    interval: int = 2               # measured iterations per window
    hysteresis: float = 0.10        # required predicted win (fraction)
    error_gate: float = 0.5         # max reconcile route-seconds error
    error_floor_s: float = 1e-3     # ignore sub-floor routes in the gate
    cooldown: int = 1               # re-measure windows after a retune
    max_retunes: Optional[int] = None   # total retune budget (None = ∞)
    wave_sizes: Optional[Sequence[int]] = None
    prefetch_depths: Optional[Sequence[int]] = None
    act_policies: Optional[Sequence[str]] = None
    path_policies: Optional[Sequence[str]] = None
    machine: Optional[MachineParams] = None  # base for unmeasured links

    def __post_init__(self):
        if int(self.interval) < 1:
            raise ValueError(f"interval={self.interval} must be >= 1")
        if float(self.hysteresis) < 0:
            raise ValueError(f"hysteresis={self.hysteresis} must be >= 0")


class AutotuneController:
    """Drives the measure → solve → swap loop for one engine (either
    ``OffloadEngine`` or ``DataParallelOffloadEngine``).

    Usage::

        ctl = AutotuneController(eng, AutotuneConfig(interval=2,
                                 prefetch_depths=(0, 1, 2)))
        for batch in batches:
            eng.train_step(batch)
            ctl.post_step()        # decides every `interval` steps

    ``post_step`` returns the decision dict at a window boundary and
    ``None`` inside a window. All decisions accumulate in
    ``eng.autotune_log`` (embedded in ``metrics_snapshot()``)."""

    def __init__(self, eng, acfg: Optional[AutotuneConfig] = None):
        self.eng = eng
        self.acfg = acfg or AutotuneConfig()
        self.retunes = 0
        self._cooldown = 0
        self._window = 0
        self._steps_in_window = 0
        self.decisions: List[dict] = []
        eng.autotune_log = self.decisions
        # the live-rate feed needs the chunk spans
        eng.tracer.enable()
        self._begin_window()

    # ---------------- window machinery ----------------
    def _ranks(self):
        return self.eng.ranks if hasattr(self.eng, "ranks") \
            else (self.eng,)

    def _begin_window(self):
        """Zero every per-window meter so the next snapshot describes
        ONLY this window (the byte counters feed reconcile; the span
        ring feeds machine_from_snapshot)."""
        for rk in self._ranks():
            rk.meter.reset()
        self.eng.reset_stats()
        self.eng.tracer.clear()
        self._steps_in_window = 0

    def post_step(self) -> Optional[dict]:
        """Call once after every ``train_step``. At a window boundary:
        snapshot, decide, maybe swap, then open a fresh window."""
        self._steps_in_window += 1
        if self._steps_in_window < int(self.acfg.interval):
            return None
        snap = self.eng.metrics_snapshot()
        decision = self.decide(snap, steps=self._steps_in_window)
        self._commit(decision)
        self._begin_window()
        return decision

    def _commit(self, decision: dict):
        self.decisions.append(decision)
        tr = self.eng.tracer
        if tr.enabled:
            tr.instant("autotune", f"autotune:{decision['action']}",
                       "autotune", action=decision["action"],
                       window=decision["window"],
                       reason=decision.get("reason", ""))
        if decision["action"] == "retune":
            self.eng.apply_plan_config(**decision["changes"])
            self.retunes += 1
            self._cooldown = int(self.acfg.cooldown)
        elif self._cooldown > 0:
            self._cooldown -= 1
        self._window += 1

    # ---------------- the decision ----------------
    def _current_knobs(self) -> Tuple[int, int, str, str]:
        ocfg = self.eng.ocfg
        return (ocfg.resolved_wave_size(),
                ocfg.resolved_prefetch_depth(),
                self.eng.act_policy,
                self._ranks()[0].ioe.path_policy)

    def _candidates(self) -> List[Tuple[int, int, str, str]]:
        """The candidate knob product. Axes not configured stay at
        their current value; wave candidates must divide M and are
        dropped under DP (DP plans are vertical — ``solve_config``
        rejects a wave there for the same reason)."""
        a = self.acfg
        W_cur, d_cur, pol_cur, pp_cur = self._current_knobs()
        M = self.eng.ocfg.num_microbatches
        dp = hasattr(self.eng, "ranks")
        waves = [W_cur] if (a.wave_sizes is None or dp) else \
            [int(w) for w in a.wave_sizes if 0 < int(w) <= M
             and M % int(w) == 0]
        depths = [d_cur] if a.prefetch_depths is None else \
            [int(d) for d in a.prefetch_depths]
        pols = [pol_cur] if a.act_policies is None else \
            [str(p) for p in a.act_policies]
        paths = [pp_cur] if a.path_policies is None else \
            [str(p) for p in a.path_policies]
        # the current knobs always lead the list, so `decide` can tell
        # "current plan infeasible" from "current plan merely not best"
        out = [(W_cur, d_cur, pol_cur, pp_cur)]
        for w in waves or [W_cur]:
            for d in depths or [d_cur]:
                for p in pols or [pol_cur]:
                    for pp in paths or [pp_cur]:
                        if (w, d, p, pp) not in out:
                            out.append((w, d, p, pp))
        return out

    def _score(self, machine: MachineParams,
               knobs: Tuple[int, int, str, str]) -> Optional[float]:
        """Predicted iteration seconds of one candidate under the live
        machine — ``None`` strictly means the LP is infeasible there
        (the candidate is unusable), never an argument error: invalid
        knob combinations were filtered in ``_candidates`` and
        ``solve_config`` raises ``ValueError`` on the rest."""
        eng = self.eng
        W, depth, pol, path_pol = knobs
        R = getattr(eng, "R", 1)
        w = engine_workload(eng.ocfg, eng.cfg, eng.P,
                            eng.dtype.itemsize, eng.act_nbytes)
        sol = solve_config(machine, w, eng.ocfg.num_microbatches,
                           eng.ocfg.alpha, num_gpus=R,
                           wave=None if R > 1 else W,
                           act_policy=pol, lookahead=depth > 0,
                           path_policy=path_pol)
        return None if sol is None else float(sol.iteration_time)

    def decide(self, snapshot: dict, steps: Optional[int] = None) -> dict:
        """Reduce one window's snapshot to a decision dict (pure
        w.r.t. engine state — ``post_step`` commits it). Exposed
        directly so scripted-snapshot tests can drive every branch."""
        a = self.acfg
        base = a.machine or self.eng.ocfg.machine or MachineParams()
        live = machine_from_snapshot(snapshot, base)
        steering = self._steering()
        decision = {
            "window": self._window,
            "step": int(self.eng.step_num),
            "machine": {"ssd_read_bw": live.ssd_read_bw,
                        "ssd_write_bw": live.ssd_write_bw},
            "paths": steering,
        }
        if self._cooldown > 0:
            decision.update(action="cooldown",
                            reason=f"{self._cooldown} window(s) left "
                                   "after the last retune")
            return decision
        if a.max_retunes is not None and self.retunes >= a.max_retunes:
            decision.update(action="hold", reason="retune budget spent")
            return decision
        # the model-trust gate: reconcile the CURRENT plan first
        from repro.obs import reconcile
        rec = reconcile(self.eng.plan, snapshot, machine=live,
                        steps=steps)
        err = route_seconds_error(rec.route_seconds_predicted,
                                  rec.route_seconds_measured,
                                  floor_s=a.error_floor_s)
        decision["route_error"] = err
        if err > a.error_gate:
            decision.update(
                action="blocked",
                reason=f"route_seconds error {err:.2f} > gate "
                       f"{a.error_gate:.2f}: the model cannot explain "
                       "the current plan")
            return decision
        # score the candidate product under the live machine
        cur = self._current_knobs()
        scored = [(knobs, self._score(live, knobs))
                  for knobs in self._candidates()]
        decision["candidates"] = [
            {"wave": k[0], "depth": k[1], "act": k[2], "path": k[3],
             "pred_s": s}
            for k, s in scored]
        feasible = [(k, s) for k, s in scored if s is not None]
        t_cur = dict(scored).get(cur)
        if not feasible:
            decision.update(action="hold",
                            reason="no candidate is LP-feasible under "
                                   "the live machine")
            return decision
        best, t_best = min(feasible, key=lambda ks: ks[1])
        decision["current"] = {"wave": cur[0], "depth": cur[1],
                               "act": cur[2], "path": cur[3],
                               "pred_s": t_cur}
        decision["best"] = {"wave": best[0], "depth": best[1],
                            "act": best[2], "path": best[3],
                            "pred_s": t_best}
        if best == cur:
            decision.update(action="hold",
                            reason="current plan is the predicted best")
            return decision
        win = (t_cur / t_best) if t_cur is not None else float("inf")
        decision["predicted_win"] = None if win == float("inf") else win
        if t_cur is not None and win < 1.0 + a.hysteresis:
            decision.update(
                action="hold",
                reason=f"predicted win {win:.3f}x under hysteresis "
                       f"{1.0 + a.hysteresis:.2f}x")
            return decision
        changes = {}
        if best[0] != cur[0]:
            changes["wave_size"] = best[0]
        if best[1] != cur[1]:
            changes["prefetch_depth"] = best[1]
        if best[2] != cur[2]:
            changes["activation_policy"] = best[2]
        if best[3] != cur[3]:
            changes["path_policy"] = best[3]
        decision.update(
            action="retune", changes=changes,
            reason=("current plan LP-infeasible under the live machine"
                    if t_cur is None else
                    f"predicted win {win:.3f}x clears hysteresis"))
        return decision

    def _steering(self) -> List[dict]:
        """The per-rank multi-path steering signal (the same backlog
        the "backlog" placement policy consumes per chunk — see the
        module header)."""
        out = []
        for rk in self._ranks():
            ioe = rk.ioe
            out.append({"least_loaded_path": ioe.least_loaded_path(),
                        "imbalance": ioe.path_imbalance(),
                        "path_policy": ioe.path_policy})
        return out
