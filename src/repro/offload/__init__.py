from repro.io import IOConfig, IOEngine, IOPriority  # noqa: F401
from repro.offload.checkpoint import (CheckpointError,  # noqa: F401
                                      load_manifest, restore_checkpoint,
                                      save_checkpoint)
from repro.offload.autotune import (AutotuneConfig,  # noqa: F401
                                    AutotuneController,
                                    route_seconds_error)
from repro.offload.dp import (DataParallelOffloadEngine,  # noqa: F401
                              shard_bounds)
from repro.offload.engine import OffloadConfig, OffloadEngine  # noqa: F401
from repro.offload.stores import (HostStore, SSDStore, TieredVector,  # noqa: F401
                                  TrafficMeter)
from repro.offload.buffers import naive_padded, pack, waste_ratio  # noqa: F401


def make_engine(cfg, ocfg, key, workdir, *, io_cfg=None, num_ranks=1):
    """The one documented construction path for offload engines.

    Builds a single-rank :class:`OffloadEngine` (``num_ranks=1``) or a
    :class:`DataParallelOffloadEngine` (``num_ranks>1``) from the same
    arguments: model config, :class:`OffloadConfig`, PRNG key, and the
    SSD workdir. ``io_cfg`` (an :class:`IOConfig`) overrides
    ``ocfg.io`` when given — handy when the storage topology (paths,
    pacing, placement policy) is decided separately from the schedule.
    Config validation is eager: a typo'd ``schedule`` /
    ``activation_policy`` / ``path_policy`` raises ``ValueError`` here,
    before any file or thread exists. ``repro.serve.ServeEngine``
    builds its I/O stack through the same configs.
    """
    import dataclasses as _dc

    if io_cfg is not None:
        ocfg = _dc.replace(ocfg, io=io_cfg)
    if num_ranks < 1:
        raise ValueError(f"num_ranks={num_ranks} must be >= 1")
    if num_ranks == 1:
        return OffloadEngine(cfg, ocfg, key, workdir)
    return DataParallelOffloadEngine(cfg, ocfg, key, workdir,
                                     ranks=num_ranks)
