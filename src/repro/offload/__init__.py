from repro.io import IOConfig, IOEngine, IOPriority  # noqa: F401
from repro.offload.autotune import (AutotuneConfig,  # noqa: F401
                                    AutotuneController,
                                    route_seconds_error)
from repro.offload.dp import (DataParallelOffloadEngine,  # noqa: F401
                              shard_bounds)
from repro.offload.engine import OffloadConfig, OffloadEngine  # noqa: F401
from repro.offload.stores import (HostStore, SSDStore, TieredVector,  # noqa: F401
                                  TrafficMeter)
from repro.offload.buffers import naive_padded, pack, waste_ratio  # noqa: F401
