"""The ONE plan executor: walks a compiled :class:`repro.core.plan.Plan`
against the coordinators / IOEngine stack.

Both engines drive their training steps through :func:`execute_plan` —
``OffloadEngine`` (single rank, any wave size) and
``DataParallelOffloadEngine`` (per-rank coordinator stacks, vertical
plans with ``ALLGATHER`` / ``REDUCE_SCATTER`` ops). The executor owns
only transient per-step state (a register file of device tensors keyed
by micro-batch, the layer-gradient accumulator, the head-gradient
folds); all persistent state — tiered vectors, coordinators, the jitted
block functions — belongs to the engine it is handed.

Determinism: the executor performs the SAME coordinator calls and
floating-point folds, in the SAME order, for a given schedule, so
losses and parameters are bit-identical (f32) across the α /
storage-ratio / DP / activation-policy axes (pinned by the
schedule-parity batteries in ``tests/test_property.py`` /
``tests/test_plan_executor.py`` / ``tests/test_act_stream.py``). The
WAVE-SIZE axis is the exception: a 1 < W < M plan GROUPS the f32
layer-gradient fold differently (per-wave partial sums parked in CPU),
so its optimizer-bound sums can differ from vertical's in the last ulp
— step-1 losses are still bitwise, later steps agree within jit
rounding (W=1 folds element-by-element in a commutative order and
stays bitwise in practice).

Activation policies: under ``act_spill`` plans the forward runs the
residual-returning block function and ``SPILL_ACT``/``FETCH_ACT``
stream each layer's vjp residuals through the ``ActivationCoordinator``
instead of recomputing backward from the checkpoint. BOTH policies
apply ``j_layer_bwd_res`` to residuals — restored or recomputed — so
spill and recompute runs are bitwise-identical (f32) in losses and
parameters by construction (pinned in ``tests/test_act_stream.py``).

Cross-stream lookahead: the compiled plan carries one hint op per
fetch-class op (``PREFETCH`` for params, ``PREFETCH_CKPT`` for backward
checkpoint tails, ``PREFETCH_ACT`` for the activation stream,
``PREFETCH_OPT`` for the α-tail optimizer state reads — see
``repro.core.plan.insert_prefetch``). Hints are pure optimization:
each one submits the matching coordinator's asynchronous read early
and moves no bytes of its own, so the executor may legally SKIP any
hint without changing a single byte counter or output bit. That is
exactly what the backpressure-adaptive gate does: before issuing a
hint it consults the owning ``IOEngine.depth()`` and skips when the
live queue says the SSD is saturated (counted in ``eng.hint_skips``).
Under ``activation_policy="auto"`` the same signal gates each
``SPILL_ACT`` per (layer, micro-batch): when the write queue is
saturated the spill is skipped (``eng.act_skips``) and that
micro-batch's backward falls back to recompute — bitwise-identical by
construction, because both policies run backward from the same vjp
residuals.

Stall metering and the span lifecycle: every op's wall-clock is
accumulated into ``eng.op_seconds[op.name]``; :func:`stall_seconds`
sums the kinds the GPU actually blocks on (the FETCH-class ops and the
waits), which is what the bench-smoke artifact reports and CI gates,
and ``repro.obs.stall_by_stream`` folds into per-stream attribution.
When the engine's shared ``repro.obs.Tracer`` is enabled, the SAME
``t_op``/``dt`` measurement that feeds ``op_seconds`` is also recorded
as one span per executed op on the executor's track, tagged with the
full plan-op identity — op kind, layer ``l``, micro-batch ``m``, wave
index (counted at the ``PHASE("fwd")`` flips), owning rank, and step —
so a Chrome trace lines the op timeline up against the I/O channel
tracks (queue-wait/transfer spans recorded by ``repro.io.engine``) and
the coordinators' hint-lifecycle spans. Each backpressure skip
(``hint_skips`` / ``act_skips``) additionally drops an instant event at
the moment of the skip. Tracing off costs one flag test at plan start;
the op loop is unchanged.

Fault discipline: a mid-plan exception (a failed chunk op surfacing
through a coordinator) must not leak device slots or host buffers into
the next step — the executor releases its registers, cancels
outstanding parameter prefetches, clears the checkpoint and activation
coordinators' device-kept/CPU state and drains optimizer requests
before re-raising. A failed ``SPILL_ACT``/``FETCH_ACT`` is SOFTER: it
degrades just that micro-batch to the recompute path (counted in
``eng.act_fallbacks``) — the checkpoint tier it needs is still intact
— and the step completes with bitwise-identical results. The
fault-injection batteries (``tests/test_plan_executor.py``,
``tests/test_act_faults.py``) drive these paths with the
``tests/test_io_faults.py`` failing backend.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import Op, Plan
from repro.obs.tracer import CAT_HINT, CAT_PLAN
from repro.offload.coordinators import _xfer

#: the executor's Chrome-trace track name (one executor thread drives
#: all ranks; per-op rank identity rides in the span args)
EXEC_TRACK = "exec"


def _ranks(eng):
    """The engine's rank stacks: the DP engine's ``ranks`` list, or the
    single-rank engine itself (it exposes the same coordinator attrs)."""
    rks = getattr(eng, "ranks", None)
    return rks if rks is not None else (eng,)


#: plan-op kinds whose handler time is GPU-blocking stall (awaiting
#: storage / collectives / drains) rather than useful compute — the
#: "stall-seconds" the lookahead exists to shrink.
STALL_OPS = frozenset(o.name for o in (
    Op.FETCH_PARAM, Op.ALLGATHER, Op.FETCH_CKPT, Op.FETCH_CKPT_BWD,
    Op.FETCH_ACT, Op.FETCH_GRAD, Op.GRAD_FETCH_ACC, Op.WAIT_OPT,
    Op.BARRIER))


def stall_seconds(op_seconds) -> float:
    """Total stall from a per-op-kind seconds map (``eng.op_seconds``)."""
    return sum(v for k, v in op_seconds.items() if k in STALL_OPS)


def _saturated(ioe, frac: float, route: str) -> bool:
    """The backpressure signal: should a lookahead hint (or an "auto"
    activation spill) on ``route`` be skipped right now?

    Two saturation conditions, either one suffices:

    * the engine's in-flight byte budget is past ``frac`` utilization
      (requests already queue at submit — adding lookahead would make
      the executor BLOCK on the very backpressure it is trying to
      dodge);
    * the per-path channels already hold more than ``frac * 16`` chunks
      of unfinished work on this route (MLP-Offload's idle-level rule:
      prefetch only INTO idle bandwidth — when the link has a standing
      backlog, an early read cannot finish early, it just steals
      link time from whatever the GPU blocks on next).

    Reads only the engine's O(1) counters (``inflight_bytes``,
    ``route_backlog``) — this is polled per hint op, so it must not
    scan queues (``IOEngine.depth()`` is the rich, occasional-use
    snapshot).
    """
    if ioe.inflight_bytes > frac * ioe.budget_bytes:
        return True
    return ioe.route_backlog(route) > frac * 16 * ioe.chunk_bytes


def execute_plan(eng, plan: Plan, tokens: np.ndarray) -> float:
    """Run one training step of ``eng`` by interpreting ``plan``.
    Returns the summed micro-batch loss (same fold order as the
    imperative engines)."""
    ocfg = eng.ocfg
    mbs = eng._split_tokens(tokens)
    eng.step_num += 1
    step = eng.step_num
    denom = jnp.asarray(float(np.prod(tokens.shape) - tokens.shape[0]),
                        jnp.float32)
    ranks = _ranks(eng)
    multi = len(ranks) > 1
    Mr = eng.Mr if multi else plan.spec.M

    def rank_of(m: int):
        return ranks[m // Mr] if multi else ranks[0]

    spill = plan.spec.act_spill     # SSDTrain-style activation stream
    bp = getattr(eng, "backpressure", 0.5)
    act_adaptive = getattr(eng, "act_adaptive", False)
    op_seconds = eng.op_seconds
    tracer = getattr(eng, "tracer", None)
    rec = tracer is not None and tracer.enabled
    wave = -1                       # becomes 0 at the first PHASE("fwd")

    def skip_evt(kind: str, op):
        """Instant event marking one backpressure skip (hint or spill)."""
        if rec:
            tracer.instant(EXEC_TRACK, f"skip:{kind}", CAT_HINT,
                           op=op.op.name, l=op.l, m=op.m)
    regs = {}                       # transient device tensors
    p_dev = None                    # current layer's params
    gacc = None                     # f32 layer-gradient accumulator
    per_mb_dp = {}                  # DP: stashed per-micro-batch dW
    head_stash = {}                 # DP: stashed (loss, d_unembed, d_norm)
    embed_stash = {}                # DP: stashed d_embed contributions
    loss_total = 0.0
    d_un = jnp.zeros_like(eng.unembed, dtype=jnp.float32)
    d_nm = jnp.zeros_like(eng.final_norm, dtype=jnp.float32)
    d_embed = jnp.zeros_like(eng.embed, dtype=jnp.float32)

    phase = None
    t0 = time.perf_counter()

    def flip(tag):
        nonlocal phase, t0
        now = time.perf_counter()
        if phase is not None:
            eng.phase_time[phase] = eng.phase_time.get(phase, 0.0) \
                + (now - t0)
        phase, t0 = tag, now

    try:
        for op in plan.ops:
            k = op.op
            t_op = time.perf_counter()
            if k is Op.FETCH_CKPT:
                regs[("x", op.m)] = \
                    rank_of(op.m).ckpt_c.get_ckpt_fwd(op.l, op.m)
            elif k is Op.FWD:
                x_in = regs.pop(("x", op.m))
                if spill:
                    # materialise the vjp residuals for the act stream
                    y, res = eng.j_layer_fwd_res(p_dev, x_in)
                    regs[("y", op.m)] = y
                    regs[("res", op.m)] = res
                else:
                    regs[("y", op.m)] = eng.j_layer_fwd(p_dev, x_in)
            elif k is Op.SPILL_ACT:
                res = regs.pop(("res", op.m))
                rk = rank_of(op.m)
                if act_adaptive and _saturated(rk.ioe, bp, "cpu->ssd"):
                    # SSDTrain's adaptive knob per (layer, micro-batch):
                    # the write queue is saturated, so streaming this
                    # residual would lengthen the critical path — drop
                    # it and let FETCH_ACT degrade this micro-batch to
                    # the recompute path (bitwise-identical results)
                    eng.act_skips += 1
                    skip_evt("act_spill", op)
                    del res
                else:
                    try:
                        rk.act_c.put(op.l, op.m, res)
                    except Exception:
                        # a failed spill degrades this micro-batch to
                        # the recompute path (its checkpoint tier is
                        # intact); drop whatever the coordinator
                        # half-tracked — the FETCH_ACT for this key
                        # then finds nothing and counts the fallback
                        rk.act_c.drop(op.l, op.m)
            elif k is Op.PREFETCH_ACT:
                rk = rank_of(op.m)
                if _saturated(rk.ioe, bp, "ssd->cpu"):
                    eng.hint_skips += 1
                    skip_evt("hint", op)
                else:
                    rk.act_c.prefetch(op.l, op.m)
            elif k is Op.PREFETCH_CKPT:
                rk = rank_of(op.m)
                if _saturated(rk.ioe, bp, "ssd->cpu"):
                    eng.hint_skips += 1
                    skip_evt("hint", op)
                else:
                    rk.ckpt_c.prefetch_bwd(op.l, op.m)
            elif k is Op.PREFETCH_OPT:
                if ocfg.alpha > 0:
                    for rk in ranks:
                        if _saturated(rk.ioe, bp, "ssd->cpu"):
                            eng.hint_skips += 1
                            skip_evt("hint", op)
                        else:
                            rk.opt_c.prefetch_late(op.l)
            elif k is Op.FETCH_ACT:
                rk = rank_of(op.m)
                try:
                    regs[("res", op.m)] = rk.act_c.get(op.l, op.m)
                except Exception:
                    # failed (or never-landed) fetch: fall back to the
                    # checkpoint re-read; BWD recomputes the residuals
                    rk.act_c.drop(op.l, op.m)
                    eng.act_fallbacks += 1
                    regs[("x", op.m)] = \
                        rk.ckpt_c.get_ckpt_bwd(op.l, op.m)
            elif k is Op.SPILL_CKPT:
                rank_of(op.m).ckpt_c.put_ckpt(op.l, op.m,
                                              regs.pop(("y", op.m)),
                                              keep_on_device=op.keep)
            elif k is Op.FETCH_CKPT_BWD:
                regs[("x", op.m)] = \
                    rank_of(op.m).ckpt_c.get_ckpt_bwd(op.l, op.m)
            elif k is Op.FETCH_GRAD:
                regs[("dy", op.m)] = \
                    rank_of(op.m).ckpt_c.get_grad(op.l, op.m)
            elif k is Op.BWD:
                # Both policies run backward from vjp residuals — spill
                # restores them from the act stream, recompute re-runs
                # the residual-returning forward on the fetched ckpt —
                # so spill/recompute gradients are bitwise-identical.
                res = regs.pop(("res", op.m), None)
                if res is None:
                    _, res = eng.j_layer_fwd_res(p_dev,
                                                 regs.pop(("x", op.m)))
                dx, dp = eng.j_layer_bwd_res(res, regs.pop(("dy", op.m)))
                if op.acc:
                    gacc = gacc + dp
                else:
                    per_mb_dp[op.m] = dp
                regs[("dx", op.m)] = dx
            elif k is Op.SPILL_GRAD:
                rank_of(op.m).ckpt_c.put_grad(op.l, op.m,
                                              regs.pop(("dx", op.m)),
                                              keep_on_device=op.keep)
            elif k is Op.DROP_CKPT:
                rank_of(op.m).ckpt_c.drop_ckpt(op.l, op.m)
            elif k is Op.PREFETCH:
                for rk in ranks:
                    if _saturated(rk.ioe, bp, "ssd->cpu"):
                        eng.hint_skips += 1
                        skip_evt("hint", op)
                    else:
                        rk.params_c.prefetch(op.l)
            elif k is Op.FETCH_PARAM:
                p_dev = ranks[0].params_c.get(op.l)
            elif k is Op.ALLGATHER:
                p_dev = eng._allgather_params(op.l)
            elif k is Op.RELEASE_PARAM:
                p_dev = None
            elif k is Op.RESET_PARAMS:
                for rk in ranks:
                    rk.params_c.reset()
            elif k is Op.EMBED_FWD:
                regs[("y", op.m)] = eng.j_embed(eng.embed,
                                                jnp.asarray(mbs[op.m]))
            elif k is Op.HEAD_BWD:
                lab, w = eng._labels(mbs[op.m])
                loss, du, dn, dx = eng.j_head_bwd(
                    eng.unembed, eng.final_norm, regs.pop(("x", op.m)),
                    lab, w, denom)
                if op.acc:
                    loss_total += float(loss)
                    d_un = d_un + du
                    d_nm = d_nm + dn
                else:
                    head_stash[op.m] = (loss, du, dn)
                regs[("dx", op.m)] = dx
            elif k is Op.EMBED_BWD:
                d = eng.j_embed_bwd(eng.embed, jnp.asarray(mbs[op.m]),
                                    regs.pop(("dy", op.m)))
                if op.acc:
                    d_embed = d_embed + d
                else:
                    embed_stash[op.m] = d
            elif k is Op.GRAD_INIT:
                gacc = jnp.zeros((eng.P,), jnp.float32)
            elif k is Op.GRAD_SPILL:
                rk = ranks[0]
                g = np.asarray(gacc)
                _xfer(rk.meter, rk.ioe, "grad", "gpu->cpu", g.nbytes)
                rk.host.put(f"gacc:{op.l}", g)
                gacc = None
            elif k is Op.GRAD_FETCH_ACC:
                rk = ranks[0]
                g_host = rk.host.pop(f"gacc:{op.l}")
                _xfer(rk.meter, rk.ioe, "grad", "cpu->gpu", g_host.nbytes)
                gacc = gacc + jnp.asarray(g_host)
            elif k is Op.WRITEBACK_GRAD:
                ranks[0].opt_c.submit_early(op.l, gacc, step)
                gacc = None
            elif k is Op.REDUCE_SCATTER:
                eng._reduce_scatter_update(op.l, per_mb_dp, step)
                per_mb_dp = {}
            elif k is Op.OPT_LATE:
                # epilogue seam (default): flush THIS step's α-tail now
                # (it was retained at WRITEBACK_GRAD) and re-arm the
                # gate, so the flush overlaps the next step's first
                # fetches. A tag="pro" op is the lookahead-off PROLOGUE
                # variant: flush the PREVIOUS step's tail at plan start
                # (same (gradient, Adam-step) pairs => bitwise-equal).
                pro = op.tag == "pro"
                if ocfg.alpha > 0 and not (pro and step <= 1):
                    for rk in ranks:
                        rk.opt_c.flush_late(op.l, step - 1 if pro
                                            else step)
                        # the ready probe keeps a hinted fetch from
                        # parking a request worker on a still-QUEUED
                        # flush (deadlock guard for deep lookahead)
                        rk.params_c.set_gate(
                            op.l,
                            (lambda c, ll: lambda: c.wait_late(ll))(
                                rk.opt_c, op.l),
                            (lambda c, ll: lambda: c.late_settled(ll))(
                                rk.opt_c, op.l))
            elif k is Op.FOLD_HEAD:
                for m in op.ms:
                    loss, du, dn = head_stash[m]
                    loss_total += float(loss)
                    d_un = d_un + du
                    d_nm = d_nm + dn
                head_stash = {}
            elif k is Op.FOLD_EMBED:
                for m in op.ms:
                    d_embed = d_embed + embed_stash[m]
                embed_stash = {}
            elif k is Op.ALLREDUCE_HEAD:
                head_bytes = int(d_embed.nbytes + d_un.nbytes
                                 + d_nm.nbytes)
                ring = 2 * (eng.R - 1) * head_bytes // eng.R
                eng._collective("head_grad", ring, ring)
            elif k is Op.HEAD_ADAM:
                for name, g in (("embed", d_embed), ("unembed", d_un),
                                ("final_norm", d_nm)):
                    st = eng.head_state[name]
                    p2, st["m"], st["v"] = eng.j_adam_dev(
                        getattr(eng, name), st["m"], st["v"], g,
                        jnp.asarray(step, jnp.int32),
                        jnp.asarray(ocfg.lr))
                    setattr(eng, name, p2)
            elif k is Op.WAIT_OPT:
                for rk in ranks:
                    rk.opt_c.wait_all()
            elif k is Op.BARRIER:
                jax.effects_barrier()
            elif k is Op.PHASE:
                if op.tag == "fwd":
                    wave += 1
                flip(op.tag)
            else:                    # pragma: no cover - compiler bug
                raise ValueError(f"unknown plan op {op!r}")
            dt = time.perf_counter() - t_op
            op_seconds[k.name] += dt
            if rec:
                # the SAME measurement op_seconds accumulates, as a span
                tracer.record(
                    EXEC_TRACK, k.name, CAT_PLAN, t_op, t_op + dt,
                    l=op.l, m=op.m, wave=wave,
                    rank=(op.m // Mr if multi and op.m >= 0 else 0),
                    step=step)
        flip(None)
    except BaseException:
        # Mid-plan failure: free the device slots and cancel in-flight
        # work so the engine can be reused or torn down cleanly instead
        # of leaking kept boundary tensors / gated prefetches. The
        # step is abandoned wholesale, so α gates and retained α-tail
        # gradients go with it (clear_gates / opt_c.clear) — a stale
        # gate or pending_grad would re-raise this step's fault (or
        # apply its gradient) inside the NEXT step. After this unwind
        # the engine accepts new steps / checkpoint restores cleanly;
        # tests/test_chaos.py pins that.
        regs.clear()
        per_mb_dp = head_stash = embed_stash = {}
        gacc = p_dev = None
        for rk in ranks:
            for fn in (rk.params_c.reset, rk.params_c.clear_gates,
                       rk.ckpt_c.clear, rk.act_c.clear, rk.opt_c.clear):
                try:
                    fn()
                except Exception:
                    pass                 # the original error propagates
        raise
    return loss_total
