"""Fig. 10 — end-to-end saturated-throughput comparison, GreedySnake
(vertical + LP config + α-delay) vs ZeRO-Infinity (horizontal), on the
paper's machine parameters.

Paper's headline numbers to validate (saturated-throughput ratios):
  GPT-65B  1x A100: 1.96x      GPT-65B  4x A100: 1.93x
  GPT-175B 1x A100: 2.53x
plus GPT-30B / GPT-65B on the A5000 machine.

Methodology: for the vertical schedule we run Algorithm 1
(find_optimal_config — LP over storage ratios, α grid, smallest
saturating n). The horizontal baseline gets its most favorable setting
(paper §6.2): the largest per-pass micro-batch that fits GPU memory
(ZeRO-Infinity recomputes full layers without a fused flash backward,
so the f32 attention-score matrix bounds it) and the best storage split
over a grid. Both throughputs are compared over the same global-batch
axis, as in the paper's figure: the axis extends to ~4x GreedySnake's
saturation batch ("well beyond the shifting point", §6.2); the
horizontal schedule keeps improving slowly past the plotted range, so
the ratio is reported at that shared endpoint, with the full curve
printed for transparency.
"""
from __future__ import annotations

from typing import Optional, Tuple

from benchmarks.common import A100_CLOUD, A5000, Reporter, per_gpu_machine
from benchmarks.fig4_batch_scaling import max_batch
from repro.configs import get_config
from repro.core.lp_search import find_optimal_config
from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  iteration_time_horizontal)

PAPER_CLAIMS = {
    ("gpt-65b", "a100-cloud", 1): 1.96,
    ("gpt-65b", "a100-cloud", 4): 1.93,
    ("gpt-175b", "a100-cloud", 1): 2.53,
}


def horizontal_tp(cfg, m: MachineParams, seq: int, num_gpus: int,
                  global_batch: int) -> Tuple[float, int, int]:
    """Best horizontal (ZeRO-Infinity-style) tokens/s per GPU at a given
    per-GPU global batch: largest feasible per-pass micro-batch, best
    storage split over a small grid."""
    mb = max_batch(cfg, m, seq, intra_ckpt=False, materialize_probs=True)
    mb = min(mb, global_batch)
    M = max(1, global_batch // mb)
    w = Workload.from_config(cfg, micro_batch=mb, seq_len=seq,
                             num_gpus=num_gpus)
    best = float("inf")
    for xp in (0.0, 0.25, 0.5, 0.75, 1.0):
        for xo in (0.0, 0.25, 0.5, 0.75, 1.0):
            for xc in (0.0, 1.0):
                t = iteration_time_horizontal(
                    w, m, M, StorageRatios(xc, xp, xo))
                best = min(best, t)
    tp = M * w.tokens_per_mb / best if best < float("inf") else 0.0
    return tp, M, mb


def run(rep: Optional[Reporter] = None, seq: int = 2048) -> None:
    rep = rep or Reporter()
    rep.section("fig10: saturated throughput, GreedySnake vs ZeRO-Infinity "
                "(perf model on the paper's machines)")
    cases = [
        ("gpt-30b", A5000, 1), ("gpt-30b", A5000, 4), ("gpt-65b", A5000, 1),
        ("gpt-65b", A100_CLOUD, 1), ("gpt-65b", A100_CLOUD, 4),
        ("gpt-175b", A100_CLOUD, 1),
    ]
    for model, m0, n_gpu in cases:
        cfg = get_config(model)
        tag = f"fig10/{model}_{m0.name}_{n_gpu}gpu"
        # per-GPU view: FSDP shards states 1/n, but the SSD/CPU is shared
        m = per_gpu_machine(m0, n_gpu)
        # GreedySnake: micro-batch 2 (paper §6.2: 1-2), Algorithm 1 config
        wv = Workload.from_config(cfg, micro_batch=2, seq_len=seq,
                                  num_gpus=n_gpu)
        res = find_optimal_config(m, wv, alphas=[0.0, 0.1, 0.2, 0.3, 0.4, 0.5],
                                  max_n=256)
        if res is None:
            rep.add(tag, "infeasible", "")
            continue
        tp_v = res.throughput_tokens_per_s
        g_sat = res.n * 2                       # samples (per GPU)
        # shared axis endpoint: 2x GreedySnake saturation batch ("well
        # beyond the shifting point", §6.2)
        g_axis = 2 * g_sat
        curve = []
        for g in (g_sat, 2 * g_sat, 4 * g_sat, 8 * g_sat):
            tp_h, M_h, mb_h = horizontal_tp(cfg, m, seq, n_gpu, g)
            curve.append((g, tp_h, M_h, mb_h))
        tp_axis = next(tp for g, tp, _, _ in curve if g == g_axis)
        mb_h = curve[0][3]
        ratio = tp_v / tp_axis if tp_axis > 0 else float("inf")
        claim = PAPER_CLAIMS.get((model, m.name, n_gpu))
        derived = (f"vertical n={res.n} alpha={res.alpha:.2f} sat@batch "
                   f"{g_sat} vs horizontal mb={mb_h} @batch {g_axis}")
        if claim:
            gap = 100 * abs(ratio - claim) / claim
            derived += f"; paper {claim:.2f}x (model gap {gap:.0f}%)"
        rep.add(f"{tag}_speedup", f"{ratio:.2f}", derived)
        rep.add(f"{tag}_curve",
                " ".join(f"{g}:{tp_v / tp:.2f}x" if tp else f"{g}:inf"
                         for g, tp, _, _ in curve),
                "speedup vs shared global-batch axis endpoint")
        flops_tok = 4 * wv.flops_per_mb / wv.tokens_per_mb
        rep.add(f"{tag}_tflops", f"{tp_v * flops_tok / 1e12:.1f}",
                "per-GPU TFLOP/s at saturation (paper measured: 63.1 "
                "65B/4GPU, 128.3 175B/4GPU)" if n_gpu == 4 else
                "per-GPU TFLOP/s at saturation")


if __name__ == "__main__":
    run()
