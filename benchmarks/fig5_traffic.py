"""Fig. 5 — GPU load/offload traffic: horizontal vs vertical scheduling
(GPT-65B, mb=8 per micro-batch, seq 2048), plus a measured validation of
the closed forms against the offload engine's byte meters on a small
model (the engine moves REAL bytes through host buffers and files).
"""
from __future__ import annotations

import tempfile
from typing import Optional

import jax

from benchmarks.common import Reporter, gb
from repro.configs import get_config
from repro.core import traffic as tr
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.offload import OffloadConfig, OffloadEngine


def run(rep: Optional[Reporter] = None, seq: int = 2048, mb: int = 8) -> None:
    rep = rep or Reporter()
    rep.section("fig5: GPU load/offload traffic, horizontal vs vertical "
                "(GPT-65B, seq 2048, mb 8)")
    cfg = get_config("gpt-65b")
    ms = tr.model_bytes(cfg)
    cs = tr.checkpoint_bytes(cfg, mb, seq)
    for M in (1, 2, 4, 8, 16, 32):
        h = tr.horizontal_traffic(ms, cs, M)
        v = tr.vertical_traffic(ms, cs, M)
        rep.add(f"fig5/load_GB_M{M}", f"{gb(h.load)}->{gb(v.load)}",
                f"horizontal->vertical ({h.load / v.load:.2f}x less)")
        rep.add(f"fig5/offload_GB_M{M}", f"{gb(h.offload)}->{gb(v.offload)}",
                f"horizontal->vertical ({h.offload / v.offload:.2f}x less)")

    # the §3.4 size argument: params per layer vs checkpoint per micro-batch
    layer_elems = cfg.layer_params(0)
    ckpt_elems = mb * seq * cfg.d_model
    rep.add("fig5/layer_params_elems", f"{layer_elems:.3e}",
            f"vs ckpt {ckpt_elems:.3e} ({layer_elems / ckpt_elems:.1f}x)")

    # ---- measured validation on the real engine (small model) ----
    rep.section("fig5-measured: engine byte counters vs closed forms "
                "(gpt-tiny, real host+file I/O)")
    tcfg = get_config("gpt-tiny")
    M_meas, mb_meas, s_meas = 4, 2, 64
    for sched in ("horizontal", "vertical"):
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(tcfg, OffloadConfig(
                schedule=sched, num_microbatches=M_meas, micro_batch=mb_meas,
                seq_len=s_meas, ratios=StorageRatios(0.5, 0.5, 0.0)),
                jax.random.PRNGKey(0), d)
            data = SyntheticLM(tcfg.vocab_size, seed=0)
            eng.meter.reset()
            eng.train_step(data.batch(M_meas * mb_meas, s_meas))
            eng.finish()
            routes = dict(eng.meter.bytes)
            ms_t = eng.L * eng.P * 4  # engine runs f32 params
            eng.close()
        pload = routes.get(("param", "cpu->gpu"), 0)
        gmove = routes.get(("grad", "gpu->cpu"), 0) + \
            routes.get(("grad", "cpu->gpu"), 0)
        expect_p = (2 * M_meas if sched == "horizontal" else 2) * ms_t
        expect_g = ((2 * M_meas - 1) if sched == "horizontal" else 1) * ms_t
        rep.add(f"fig5/measured_param_load_{sched}",
                f"{pload}", f"expected {expect_p} "
                f"({'OK' if pload == expect_p else 'MISMATCH'})")
        rep.add(f"fig5/measured_grad_move_{sched}",
                f"{gmove}", f"expected {expect_g} "
                f"({'OK' if gmove == expect_g else 'MISMATCH'})")


if __name__ == "__main__":
    run()
