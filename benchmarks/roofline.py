"""§Roofline: derive the three roofline terms for every (arch x shape)
from the dry-run's compiled artifacts (experiments/dryrun/*.json).

Per pair (single-pod 16x16 mesh, v5e constants):
  compute term    = HLO_FLOPs / (chips x 197 TF/s)   [= flops/dev / peak]
  memory term     = HLO_bytes / (chips x 819 GB/s)   [= bytes/dev / bw]
  collective term = collective_bytes / (chips x 50 GB/s/link)

``cost_analysis()`` / the HLO parse are per-device quantities of the SPMD
module, so dividing the global totals by ``chips`` is identical to using
the per-device numbers directly; we use the latter.

MODEL_FLOPS: 6·N_active·D for training (fwd 2 + bwd 4), 2·N_active·D for
prefill, 2·N_active·B for single-token decode. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste (full per-layer
remat alone caps the train ratio at 6/8 = 0.75).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS, Reporter
from repro.configs import INPUT_SHAPES


def collective_bytes(colls: Dict) -> float:
    """Sum operand+result bytes over every collective kind (per device).

    For all-reduce/all-gather HLO the operand list includes the input
    buffers; result bytes cover the gathered output. Using their sum is a
    conservative upper bound on link traffic per device.
    """
    total = 0.0
    for k, v in colls.items():
        total += v.get("result_bytes", 0.0) + v.get("operand_bytes", 0.0)
    return total


def model_flops(rec: Dict) -> float:
    shp = INPUT_SHAPES[rec["shape"]]
    n_active = rec["active_params"]
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shp.global_batch


def analyze_record(rec: Dict) -> Dict:
    chips = rec["chips"]
    comp_s = rec["flops_per_device"] / V5E_PEAK_FLOPS
    mem_s = rec["bytes_accessed_per_device"] / V5E_HBM_BW
    coll_s = collective_bytes(rec["collectives"]) / V5E_ICI_BW
    terms = {"compute": comp_s, "memory": mem_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = rec["flops_per_device"] * chips
    util = mf / hlo_total if hlo_total else 0.0
    bound_s = max(terms.values())
    # roofline fraction: useful-compute time / bound time (1.0 = ideal
    # compute-bound execution with zero redundant FLOPs)
    useful_s = (mf / chips) / V5E_PEAK_FLOPS
    frac = useful_s / bound_s if bound_s else 0.0
    return {
        **{k: v for k, v in rec.items() if k in
           ("arch", "shape", "mesh", "chips", "schedule")},
        "sharding": rec.get("sharding", "tp"),
        "compute_s": comp_s,
        "memory_s": mem_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops": hlo_total,
        "useful_ratio": util,
        "roofline_fraction": frac,
        "peak_gb_per_dev": rec["memory"]["peak_estimate_bytes"] / 1e9,
        "note": _note(dominant, terms, util, rec),
    }


def _note(dominant: str, terms: Dict, util: float, rec: Dict) -> str:
    if dominant == "collective":
        return ("reduce ICI traffic: shard params on fewer axes / use "
                "reduce-scatter grads instead of all-reduce")
    if dominant == "memory":
        if INPUT_SHAPES[rec["shape"]].kind == "decode":
            return ("decode is KV/state-bandwidth bound by nature; shrink "
                    "per-device cache bytes (more model-axis sharding or "
                    "quantized cache)")
        return "fuse ops / better layouts to cut HBM bytes per FLOP"
    if util < 0.6:
        return ("compute-bound but wasteful: relax remat policy "
                "(save more activations) to cut recompute FLOPs")
    return "near roofline: only micro-level kernel tuning remains"


def load_records(dryrun_dir: str = "experiments/dryrun",
                 mesh: str = "16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh:
            recs.append(rec)
    return recs


def run(rep: Optional[Reporter] = None,
        dryrun_dir: str = "experiments/dryrun",
        csv_out: str = "experiments/roofline.csv") -> List[Dict]:
    rep = rep or Reporter()
    rep.section("roofline (single-pod 16x16, v5e constants)")
    rows = [analyze_record(r) for r in load_records(dryrun_dir)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["sharding"]))
    hdr = ("arch,shape,sharding,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_fraction")
    print(hdr, flush=True)
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['sharding']},{r['compute_s']:.4e},"
              f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f}",
              flush=True)
        rep.rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['sharding']}",
            "value": f"{r['roofline_fraction']:.3f}",
            "derived": f"dominant={r['dominant']}"})
    if csv_out:
        os.makedirs(os.path.dirname(csv_out), exist_ok=True)
        import csv as _csv
        keys = ["arch", "shape", "mesh", "chips", "schedule", "sharding",
                "compute_s", "memory_s", "collective_s", "dominant",
                "model_flops", "hlo_flops", "useful_ratio",
                "roofline_fraction", "peak_gb_per_dev", "note"]
        with open(csv_out, "w", newline="") as f:
            w = _csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            for r in rows:
                w.writerow({k: r[k] for k in keys})
    return rows


if __name__ == "__main__":
    run()
