"""Benchmark harness: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig10      # one benchmark

Emits ``name,value,derived`` CSV rows; the roofline table additionally
writes experiments/roofline.csv.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import Reporter


def main() -> None:
    from benchmarks import (bench_engine, bench_kernels, fig4_batch_scaling,
                            fig5_traffic, fig10_throughput, fig11_delayed_opt,
                            fig12_ssd_only, roofline)
    suites = {
        "fig4": fig4_batch_scaling.run,
        "fig5": fig5_traffic.run,
        "fig10": fig10_throughput.run,
        "fig11": fig11_delayed_opt.run,
        "fig12": fig12_ssd_only.run,
        "roofline": roofline.run,
        "engine": bench_engine.run,
        "kernels": bench_kernels.run,
    }
    want = sys.argv[1:] or list(suites)
    rep = Reporter()
    print("name,value,derived")
    failed = []
    for name in want:
        try:
            suites[name](rep)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    rep.dump_csv("bench_results.csv")
    if failed:
        print(f"\nFAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)
    print(f"\nall {len(want)} benchmark suites completed; "
          f"{len(rep.rows)} rows -> bench_results.csv")


if __name__ == "__main__":
    main()
