"""Benchmark data-parallel sharded offload: N ranks × N SSD path sets.

The Fig. 10-style scaling story in three measurements:

1. **Aggregate SSD throughput** (the headline): R rank stacks — each an
   `IOEngine` + `SSDStore` over its OWN path set — fetch/spill their
   1/R shards CONCURRENTLY. Per-path bandwidth is token-bucket paced to
   SSD speed (this container's filesystem runs at page-cache speed, so
   the regime the paper's multi-path claim addresses — one path
   saturated — must be simulated; the pacing is per rank engine, like
   real per-device bandwidth). A correctly concurrent DP stack scales
   aggregate throughput ~R×; a serialized one would stay at 1×.
   Target: >= 1.6x going from R=1 to R=2.
2. **Raw filesystem numbers** (reference): the same concurrent shard
   traffic uncapped. On this 2-core container both configurations are
   memory-bus bound, so expect little scaling — included so the capped
   numbers can't be mistaken for free speedup.
3. **Model curve**: predicted tokens/s for R = 1..8 from
   `iteration_time_vertical_dp` on a GPT-65B-ish workload (the shape of
   the paper's 1.93x-over-ZeRO-Infinity multi-GPU result).

    PYTHONPATH=src python benchmarks/bench_dp.py [--size-mb 96]
        [--ranks 1 2 4] [--cap-mbs 200] [--chunk-kb 1024] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time
from typing import List, Optional, Tuple

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import Reporter  # noqa: E402

from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  iteration_time_vertical_dp)
from repro.io import IOConfig, IOEngine, IOPriority
from repro.offload.dp import shard_bounds
from repro.offload.stores import SSDStore, TrafficMeter


def _rank_stacks(root: str, R: int, chunk: int,
                 cap: Optional[float]) -> List[SSDStore]:
    bw = {"cpu->ssd": cap, "ssd->cpu": cap} if cap else {}
    stacks = []
    for r in range(R):
        p = os.path.join(root, f"rank{r}")
        eng = IOEngine(IOConfig(paths=[p], chunk_bytes=chunk, bandwidth=bw))
        stacks.append(SSDStore(p, TrafficMeter(), engine=eng))
    return stacks


def measure_aggregate(R: int, nbytes: int, chunk: int,
                      cap: Optional[float], reps: int = 3
                      ) -> Tuple[float, float]:
    """Best-of-reps aggregate (write, read) bytes/s for R ranks moving
    their 1/R shards concurrently — every rank's request is submitted to
    its own engine before any is awaited, exactly like the DP engine's
    shard prefetch."""
    arr = np.random.default_rng(0).integers(0, 255, nbytes, dtype=np.uint8)
    shards = [arr[lo:hi] for lo, hi in shard_bounds(nbytes, R)]
    outs = [np.empty(s.size, np.uint8) for s in shards]
    best_w = best_r = float("inf")
    with tempfile.TemporaryDirectory(prefix="bench_dp_") as root:
        stacks = _rank_stacks(root, R, chunk, cap)
        for rep in range(reps):
            t0 = time.perf_counter()
            reqs = [s.engine.submit(
                        (lambda s=s, sh=sh, rep=rep:
                         s.write(f"x{rep}", sh, "opt")),
                        priority=IOPriority.OPTIMIZER_STATE,
                        nbytes=sh.nbytes)
                    for s, sh in zip(stacks, shards)]
            for q in reqs:
                q.result()
            best_w = min(best_w, time.perf_counter() - t0)
            t0 = time.perf_counter()
            reqs = [s.engine.submit(
                        (lambda s=s, o=o, rep=rep:
                         s.read(f"x{rep}", "opt", out=o)),
                        priority=IOPriority.PARAM_FETCH, nbytes=o.nbytes)
                    for s, o in zip(stacks, outs)]
            for q in reqs:
                q.result()
            best_r = min(best_r, time.perf_counter() - t0)
        for s in stacks:
            s.close()
    return nbytes / best_w, nbytes / best_r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=96)
    ap.add_argument("--ranks", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--cap-mbs", type=float, default=200.0)
    ap.add_argument("--chunk-kb", type=int, default=1024)
    ap.add_argument("--csv", default="")
    args = ap.parse_args()

    rep = Reporter()
    nbytes = args.size_mb << 20
    chunk = args.chunk_kb << 10
    cap = args.cap_mbs * 1e6

    # ---- 1. aggregate SSD throughput, per-path SSD-speed pacing ----
    rep.section(f"aggregate throughput, {args.size_mb} MB total, "
                f"per-path cap {args.cap_mbs:.0f} MB/s (simulated SSD)")
    capped = {}
    for R in args.ranks:
        w, r = measure_aggregate(R, nbytes, chunk, cap)
        capped[R] = (w, r)
        rep.add(f"agg_write_MBps_R{R}", f"{w / 1e6:.0f}")
        rep.add(f"agg_read_MBps_R{R}", f"{r / 1e6:.0f}")
    if 1 in capped and 2 in capped:
        sw = capped[2][0] / capped[1][0]
        sr = capped[2][1] / capped[1][1]
        ok = "PASS" if min(sw, sr) >= 1.6 else "FAIL"
        rep.add("agg_scaling_R1_to_R2_write", f"{sw:.2f}",
                f"target >= 1.6x: {ok}")
        rep.add("agg_scaling_R1_to_R2_read", f"{sr:.2f}",
                f"target >= 1.6x: {ok}")

    # ---- 2. raw filesystem (reference; page-cache speed, 2 cores) ----
    rep.section("raw filesystem reference (uncapped)")
    for R in args.ranks:
        w, r = measure_aggregate(R, nbytes, chunk, cap=None)
        rep.add(f"raw_write_GBps_R{R}", f"{w / 1e9:.2f}")
        rep.add(f"raw_read_GBps_R{R}", f"{r / 1e9:.2f}")

    # ---- 3. Fig. 10-style model curve (GPT-65B-ish workload) ----
    rep.section("perf-model scaling curve (GPT-65B-ish, vertical DP)")
    ms = 65e9 * 2
    w65 = Workload(ms=ms, cs=2.6e9, os_bytes=65e9 * 12,
                   grad_bytes=65e9 * 4, flops_per_mb=2 * 65e9 * 2048,
                   tokens_per_mb=2048, n_layers=80)
    m = MachineParams()
    x = StorageRatios(0.3, 0.1, 0.2)
    M = 8
    base = None
    for R in (1, 2, 4, 8):
        t = iteration_time_vertical_dp(w65, m, M, 0.2, x, R=R)
        tp = M * w65.tokens_per_mb / t
        base = base or tp
        rep.add(f"model_tokens_per_s_R{R}", f"{tp:.0f}",
                f"speedup vs R=1: {tp / base:.2f}x")

    if args.csv:
        rep.dump_csv(args.csv)


if __name__ == "__main__":
    main()
