"""Gate a ``bench_engine.py --smoke --json`` run against the checked-in
baseline: any cell whose smoke throughput drops more than ``tolerance``
(default 20%) below its baseline fails the build — offload systems
regress silently unless per-route traffic and throughput numbers are
checked on every push (MLP-Offload's lesson). Cells present in only one
file are reported but do not fail (a new schedule/policy lands before
its baseline).

    python benchmarks/check_smoke.py bench_smoke.json \
        --baseline benchmarks/baseline_smoke.json [--tolerance 0.2]

Exit status: 0 pass, 1 regression.

Refresh the baseline by re-running the smoke on the reference runner
and committing the JSON:

    python benchmarks/bench_engine.py --smoke --json \
        benchmarks/baseline_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(measured: dict, baseline: dict, tolerance: float) -> list:
    """Return a list of (cell, measured_tps, baseline_tps, verdict)
    rows; verdict is "ok", "REGRESSION", or "no-baseline"/"missing"."""
    rows = []
    m_cells = measured.get("cells", {})
    b_cells = baseline.get("cells", {})
    for cell in sorted(set(m_cells) | set(b_cells)):
        m = m_cells.get(cell, {}).get("tokens_per_s")
        b = b_cells.get(cell, {}).get("tokens_per_s")
        if m is None:
            rows.append((cell, None, b, "missing"))
        elif b is None:
            rows.append((cell, m, None, "no-baseline"))
        elif m < (1.0 - tolerance) * b:
            rows.append((cell, m, b, "REGRESSION"))
        else:
            rows.append((cell, m, b, "ok"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("measured", help="bench_engine --smoke --json output")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional throughput drop (0.2 = 20%%)")
    args = ap.parse_args(argv)
    with open(args.measured) as f:
        measured = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    rows = compare(measured, baseline, args.tolerance)
    width = max(len(r[0]) for r in rows) if rows else 10
    bad = 0
    for cell, m, b, verdict in rows:
        ms = f"{m:10.0f}" if m is not None else "         -"
        bs = f"{b:10.0f}" if b is not None else "         -"
        print(f"  {cell:<{width}}  measured {ms} tok/s   "
              f"baseline {bs} tok/s   {verdict}")
        if verdict == "REGRESSION":
            bad += 1
        elif verdict == "missing":
            print(f"    note: baseline cell {cell!r} missing from the "
                  "measured run — did a schedule disappear?")
            bad += 1
    if bad:
        print(f"FAIL: {bad} cell(s) regressed more than "
              f"{args.tolerance:.0%} vs {args.baseline}")
        return 1
    print(f"PASS: all cells within {args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
