"""Gate a ``bench_engine.py --smoke --json`` run against the checked-in
baseline: any cell whose smoke throughput drops more than ``tolerance``
(default 20%) below its baseline — or whose measured stall-seconds grow
past ``stall-tolerance`` (default 100%, plus a 50 ms absolute floor so
micro-stalls cannot flap CI) — fails the build. Offload systems regress
silently unless per-route traffic, throughput, AND stall numbers are
checked on every push (MLP-Offload's lesson). Cells present in only one
file are reported but do not fail (a new schedule/policy lands before
its baseline). Boolean flags a cell carries (``path_sum_ok`` byte
conservation, the serve cell's ``serve_ok`` three-way KV invariant,
the degraded-mode cells' ``chaos_bitwise_ok`` and ``failover_ok``)
gate absolutely: False anywhere fails the build, and the pathkill
cell's degraded/healthy throughput ratio is floored at
``DEGRADED_FLOOR_GATE``. A cell that carries no ``tokens_per_s`` in
EITHER file (boolean-only cells) skips the relative throughput gate
instead of failing as missing. Two informational columns from ``metrics_snapshot()``
ride along ungated: the prefetch hit rate and the top stall stream
(which plan stream owns the blocked seconds), so a stall-gate failure
arrives with its attribution in the same table.

    python benchmarks/check_smoke.py bench_smoke.json \
        --baseline benchmarks/baseline_smoke.json [--tolerance 0.2] \
        [--stall-tolerance 1.0]

Exit status: 0 pass, 1 regression.

Refresh the baseline (runs the smoke battery and rewrites the JSON,
stamping the refresh command into its header):

    python benchmarks/check_smoke.py --update \
        [--baseline benchmarks/baseline_smoke.json]

or, to promote an already-measured run: append ``--update`` to the
normal invocation and the measured file is copied over the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

STALL_FLOOR_S = 0.05        # absolute slack under the stall gate

#: the cross-stream-lookahead A/B acceptance floor (absolute, on the
#: measured run — not relative to the baseline): the paced-SSD smoke
#: at α>0 must show at least this tokens/s ratio with hints on vs off
LOOKAHEAD_GAIN_GATE = 1.10

#: the online-autotuner recovery floor (absolute, on the measured
#: run): starting from the mis-specified machine's hand config, the
#: controller's measure -> LP re-solve -> mid-training plan swap must
#: bring the paced-SSD smoke back to at least this fraction of the
#: hand-tuned engine's tokens/s (they time INTERLEAVED iterations, so
#: the ratio is drift-free; ~1.0 when the swap lands, ~0.7 when the
#: controller fails to act)
AUTOTUNE_RECOVERY_GATE = 0.9

#: the dynamic-placement floor (absolute, on the measured run): on the
#: heterogeneous 2-path device (per-path token buckets at a 4:1 rate
#: split, NO route caps) the ``path_policy="backlog"`` engine must beat
#: the static ``i % P`` layout by at least this tokens/s ratio. Static
#: stripes half the bytes onto the slow path, so the device degrades
#: toward 2x the slow cap; backlog placement drains toward the
#: sum-of-caps roofline (the perfmodel prices exactly this split, see
#: ``machine_for_path_policy``). The cells also carry ``path_sum_ok``:
#: per-path chunk meters must sum byte-exactly to their route totals
#: (``obs.reconcile``'s conservation check) — a False anywhere fails
#: the build even if the speedup holds, because a placement layer that
#: leaks bytes between meters is wrong no matter how fast it is.
PATH_PLACEMENT_GAIN_GATE = 1.3

#: the degraded-mode floor (absolute, on the measured run): after one
#: of the two EQUAL-cap paths is killed mid-run, the streaming
#: workload's degraded/healthy throughput ratio must stay above this.
#: The survivor holds half the aggregate token-bucket caps, so the
#: ratio lands near 0.5 when write failover re-places the dead path's
#: chunks promptly; a failover layer that wedges, retries forever, or
#: serializes behind the dead channel drives it toward 0. The cell
#: also carries ``failover_ok`` (post-kill round trips bitwise,
#: ``chunk_failovers > 0``, no leaked in-flight budget) and its
#: sibling training cell carries ``chaos_bitwise_ok`` (losses under
#: transient chaos bitwise-equal to the fault-free twin) — both gate
#: absolutely, like ``path_sum_ok``.
DEGRADED_FLOOR_GATE = 0.3

REFRESH_CMD = "python benchmarks/check_smoke.py --update"


def compare(measured: dict, baseline: dict, tolerance: float,
            stall_tolerance: float) -> list:
    """Return a list of (cell, metric, measured, baseline, verdict)
    rows; verdict is "ok", "REGRESSION", or "no-baseline"/"missing"."""
    rows = []
    m_cells = measured.get("cells", {})
    b_cells = baseline.get("cells", {})
    for cell in sorted(set(m_cells) | set(b_cells)):
        if cell not in m_cells:
            rows.append((cell, "tokens_per_s", None,
                         b_cells[cell].get("tokens_per_s"), "missing"))
            continue
        m = m_cells[cell].get("tokens_per_s")
        b = b_cells.get(cell, {}).get("tokens_per_s")
        if m is None and b is not None:
            rows.append((cell, "tokens_per_s", None, b, "missing"))
        elif m is not None and b is None:
            rows.append((cell, "tokens_per_s", m, None, "no-baseline"))
        elif m is not None and m < (1.0 - tolerance) * b:
            rows.append((cell, "tokens_per_s", m, b, "REGRESSION"))
        elif m is not None:
            rows.append((cell, "tokens_per_s", m, b, "ok"))
        # (m and b both absent: a boolean-only cell — its gates are the
        # flag rows below, there is no throughput to compare)
        # the stall gate: wall-clock seconds the executor spent blocked
        # on storage per iteration (the new per-op meters); only gated
        # when both files carry the column
        ms = m_cells.get(cell, {}).get("stall_s_per_iter")
        bs = b_cells.get(cell, {}).get("stall_s_per_iter")
        if ms is not None and bs is not None:
            limit = bs * (1.0 + stall_tolerance) + STALL_FLOOR_S
            verdict = "REGRESSION" if ms > limit else "ok"
            rows.append((cell, "stall_s", ms, bs, verdict))
        # informational columns from metrics_snapshot(): the prefetch
        # hit rate and WHICH stream the stall seconds sit on — never
        # gated (timing-dependent), always shown so a stall regression
        # row above comes with its attribution
        mh = m_cells.get(cell, {}).get("prefetch_hit_rate")
        bh = b_cells.get(cell, {}).get("prefetch_hit_rate")
        if mh is not None:
            rows.append((cell, "hit_rate", mh, bh, "ok"))
        mt = m_cells.get(cell, {}).get("top_stall_stream")
        bt = b_cells.get(cell, {}).get("top_stall_stream")
        if mt is not None:
            rows.append((cell, "top_stall", mt, bt, "ok"))
        # per-path byte conservation: cells that carry the flag must
        # carry it True (the bench computes it from obs.reconcile —
        # sum of per-path chunk meters == route totals, byte-exact)
        mp = m_cells.get(cell, {}).get("path_sum_ok")
        if mp is not None:
            rows.append((cell, "path_sum_ok", str(bool(mp)), "True",
                         "ok" if mp else "REGRESSION"))
        # the serve three-way byte invariant: cells that carry the flag
        # must carry it True (per-step plan_traffic predictions ==
        # measured meters == traffic.kv_traffic closed form, exact) —
        # and the KV tier hit-rate rides along informational, so a
        # serve throughput regression arrives with its tier mix
        mso = m_cells.get(cell, {}).get("serve_ok")
        if mso is not None:
            rows.append((cell, "serve_ok", str(bool(mso)), "True",
                         "ok" if mso else "REGRESSION"))
        mk = m_cells.get(cell, {}).get("kv_hit_rate")
        if mk is not None:
            rows.append((cell, "kv_hit_rate", mk,
                         b_cells.get(cell, {}).get("kv_hit_rate"), "ok"))
        # the degraded-mode booleans: transient chaos must be absorbed
        # bitwise (retry moves the same bytes to the same place), and a
        # mid-run path kill must fail writes over to the survivor with
        # post-kill round trips bitwise and no leaked budget
        for flag in ("chaos_bitwise_ok", "failover_ok"):
            mf = m_cells.get(cell, {}).get(flag)
            if mf is not None:
                rows.append((cell, flag, str(bool(mf)), "True",
                             "ok" if mf else "REGRESSION"))
    # the lookahead A/B acceptance gate (absolute, within the measured
    # run): hints on must beat hints off on the paced-SSD cells
    la = m_cells.get("paced_alpha_lookahead", {}).get("tokens_per_s")
    nl = m_cells.get("paced_alpha_nolookahead", {}).get("tokens_per_s")
    if la is not None and nl is not None and nl > 0:
        gain = la / nl
        rows.append(("lookahead_ab", "speedup_x", gain,
                     LOOKAHEAD_GAIN_GATE,
                     "ok" if gain >= LOOKAHEAD_GAIN_GATE
                     else "REGRESSION"))
    # the autotune recovery gate (absolute, within the measured run):
    # the controller-adapted engine must reach the hand-tuned one
    ht = m_cells.get("paced_autotune_handtuned", {}).get("tokens_per_s")
    at = m_cells.get("paced_autotune_adaptive", {}).get("tokens_per_s")
    if ht is not None and at is not None and ht > 0:
        ratio = at / ht
        rows.append(("autotune_ab", "recovery_x", ratio,
                     AUTOTUNE_RECOVERY_GATE,
                     "ok" if ratio >= AUTOTUNE_RECOVERY_GATE
                     else "REGRESSION"))
    # the dynamic-placement gate (absolute, within the measured run):
    # backlog placement must beat the static stripe layout on the
    # heterogeneous (4:1 per-path paced) device
    st = m_cells.get("paced_path_static", {}).get("tokens_per_s")
    bl = m_cells.get("paced_path_backlog", {}).get("tokens_per_s")
    if st is not None and bl is not None and st > 0:
        gain = bl / st
        rows.append(("path_placement_ab", "speedup_x", gain,
                     PATH_PLACEMENT_GAIN_GATE,
                     "ok" if gain >= PATH_PLACEMENT_GAIN_GATE
                     else "REGRESSION"))
    # the degraded-mode floor (absolute, within the measured run): the
    # pathkill cell's degraded/healthy throughput ratio must stay above
    # the floor — failover that wedges drives it toward 0
    dr = m_cells.get("paced_degraded_pathkill", {}).get("degraded_ratio")
    if dr is not None:
        rows.append(("degraded_ab", "degraded_x", dr,
                     DEGRADED_FLOOR_GATE,
                     "ok" if dr >= DEGRADED_FLOOR_GATE
                     else "REGRESSION"))
    return rows


def refresh(baseline_path: str, measured: dict | None) -> int:
    """--update: rewrite the baseline from a measured run (or by
    running the smoke battery right here — through the SAME
    ``run_smoke(json_path=...)`` artifact writer CI uses, so the
    config header always describes how the cells were measured)."""
    if measured is None:
        import os
        sys.path.insert(0, os.path.dirname(__file__))
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        from bench_engine import run_smoke
        run_smoke(json_path=baseline_path)
        with open(baseline_path) as f:
            measured = json.load(f)
    measured = {"refresh_with": REFRESH_CMD, **{k: v for k, v in
                                               measured.items()
                                               if k != "refresh_with"}}
    with open(baseline_path, "w") as f:
        json.dump(measured, f, indent=2)
        f.write("\n")
    print(f"baseline refreshed: {baseline_path} "
          f"({len(measured.get('cells', {}))} cells)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("measured", nargs="?", default=None,
                    help="bench_engine --smoke --json output (omit with "
                         "--update to run the smoke battery here)")
    ap.add_argument("--baseline", default="benchmarks/baseline_smoke.json")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional throughput drop (0.2 = 20%%)")
    ap.add_argument("--stall-tolerance", type=float, default=1.0,
                    help="allowed fractional stall-seconds growth vs "
                         "baseline (1.0 = stall may double) on top of a "
                         f"{STALL_FLOOR_S * 1000:.0f} ms absolute floor")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the measured run "
                         "(or from a fresh smoke run when no measured "
                         "file is given) instead of gating")
    args = ap.parse_args(argv)
    measured = None
    if args.measured is not None:
        with open(args.measured) as f:
            measured = json.load(f)
    if args.update:
        return refresh(args.baseline, measured)
    if measured is None:
        ap.error("a measured JSON is required unless --update is given")
    with open(args.baseline) as f:
        baseline = json.load(f)
    rows = compare(measured, baseline, args.tolerance,
                   args.stall_tolerance)
    width = max(len(r[0]) for r in rows) if rows else 10
    bad = 0
    units = {"tokens_per_s": "tok/s", "stall_s": "s/iter",
             "speedup_x": "x (gate)", "recovery_x": "x (gate)",
             "degraded_x": "x (gate)",
             "hit_rate": "", "top_stall": "(info)",
             "path_sum_ok": "(gate)", "serve_ok": "(gate)",
             "chaos_bitwise_ok": "(gate)", "failover_ok": "(gate)",
             "kv_hit_rate": "(info)"}

    def fmt(v):
        if v is None:
            return "         -"
        if isinstance(v, str):
            return f"{v:>10}"
        return f"{v:10.3f}"

    for cell, metric, m, b, verdict in rows:
        unit = units.get(metric, "")
        ms = fmt(m)
        bs = fmt(b)
        print(f"  {cell:<{width}} {metric:<12} measured {ms} {unit}   "
              f"baseline {bs} {unit}   {verdict}")
        if verdict == "REGRESSION":
            bad += 1
        elif verdict == "missing":
            print(f"    note: baseline cell {cell!r} missing from the "
                  "measured run — did a schedule disappear?")
            bad += 1
    if bad:
        print(f"FAIL: {bad} metric(s) regressed past the gates "
              f"(throughput -{args.tolerance:.0%}, stall "
              f"+{args.stall_tolerance:.0%}) vs {args.baseline}")
        return 1
    print(f"PASS: all cells within the gates (throughput "
          f"-{args.tolerance:.0%}, stall +{args.stall_tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
