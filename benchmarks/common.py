"""Shared helpers for the benchmark harness.

Machine presets mirror the paper's two evaluation servers (Tab. 1):
* ``A100_CLOUD``  — Machine 2: A100-40GB, 400 GB DDR4, PCIe Gen4,
  4 TB cloud NVMe (≈6/3 GB/s read/write), dual Xeon 8462Y+.
* ``A5000`` — Machine 1: A5000-24GB, 256 GB DDR4, PCIe Gen4,
  PM9A3 3.84 TB (≈6.9/4.1 GB/s), dual EPYC 7302.

GPU FLOP rates are *sustained* matmul rates (not datasheet peaks), the
quantity Algorithm 1's benchmarking phase measures on the real machine.

TPU v5e roofline constants (the dry-run target):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.
"""
from __future__ import annotations

import csv
import io
import time
from typing import Callable, Dict, List

from repro.core.perfmodel import MachineParams

A100_CLOUD = MachineParams(name="a100-cloud", gpu_flops=140e12, pcie_bw=24e9,
                           ssd_read_bw=4.0e9, ssd_write_bw=2.0e9,
                           cpu_adam_bw=8.0e9, cpu_mem=400e9, gpu_mem=40e9)
A5000 = MachineParams(name="a5000", gpu_flops=55e12, pcie_bw=24e9,
                      ssd_read_bw=6.9e9, ssd_write_bw=4.1e9,
                      cpu_adam_bw=5.0e9, cpu_mem=256e9, gpu_mem=24e9)


def per_gpu_machine(m: MachineParams, num_gpus: int) -> MachineParams:
    """Per-GPU view of a multi-GPU server: each GPU keeps its own PCIe
    link and compute, but the host SSD, CPU-Adam throughput, and DRAM
    are SHARED across the data-parallel ranks (paper Tab. 1 servers)."""
    import dataclasses
    return dataclasses.replace(
        m, ssd_read_bw=m.ssd_read_bw / num_gpus,
        ssd_write_bw=m.ssd_write_bw / num_gpus,
        cpu_adam_bw=m.cpu_adam_bw / num_gpus,
        cpu_mem=m.cpu_mem / num_gpus)

# TPU v5e (per chip)
V5E_PEAK_FLOPS = 197e12       # bf16
V5E_HBM_BW = 819e9            # bytes/s
V5E_ICI_BW = 50e9             # bytes/s per link


class Reporter:
    """Collects ``name,value,derived`` rows and prints them as CSV."""

    def __init__(self) -> None:
        self.rows: List[Dict[str, str]] = []

    def add(self, name: str, value, derived: str = "") -> None:
        self.rows.append({"name": name, "value": value, "derived": derived})
        print(f"{name},{value},{derived}", flush=True)

    def section(self, title: str) -> None:
        print(f"\n# --- {title} ---", flush=True)

    def dump_csv(self, path: str) -> None:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=["name", "value", "derived"])
            w.writeheader()
            w.writerows(self.rows)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock seconds of ``fn(*args)`` (post-warmup)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def gb(x: float) -> str:
    return f"{x / 1e9:.2f}"
