"""Fig. 4 — batch-size scaling limits of the single forward-backward
schedule (paper §3.2), GPT-65B on the A100 machine.

Model: under per-layer activation checkpointing the backward of one
layer must hold the recovered intra-layer activations of the whole batch
in GPU memory; the largest operator's working set caps the batch.
Adding an extra checkpoint at the attention/FFN boundary (Ratel-style)
roughly halves the recovered working set (the FFN half dominates), so
the max batch grows ~1.5x — but every checkpoint boundary now swaps TWO
tensors per layer and each is 1.5x larger, a 3x traffic inflation
(paper: 20 GB -> 60 GB per GPU). Even so, throughput stays below the
optimizer-I/O saturation point (§3.2 "fundamentally unsustainable").
"""
from __future__ import annotations

from typing import Optional

from benchmarks.common import A100_CLOUD, Reporter
from repro.configs import get_config
from repro.core import traffic as tr
from repro.core.perfmodel import MachineParams, Workload

BYTES = tr.BYTES_LOW


def act_working_set_per_sample(cfg, seq: int, *, intra_ckpt: bool,
                               materialize_probs: bool = False) -> int:
    """Recovered-activation bytes per sample for one layer's backward.

    Counted tensors (GPT, GELU 4x MLP): ln1, q, k, v, attn-out, proj-out,
    ln2, ffn-up (4d), gelu (4d), ffn-down => (7 + 8)·d per token in
    low precision. With the intra-layer checkpoint, the attention half
    and the FFN half are recovered separately; the FFN half (2·4d + 2d)
    dominates. ``materialize_probs`` adds the f32 H·S·S attention-score
    matrix (systems without a fused flash backward — the ZeRO-Infinity
    setting whose measured max batch the paper reports).
    """
    d = cfg.d_model
    full = (7 * d + 2 * cfg.d_ff) * BYTES * seq
    half = (2 * cfg.d_ff + 2 * d) * BYTES * seq  # FFN sub-block working set
    per = half if intra_ckpt else full
    if materialize_probs and not intra_ckpt:
        per += cfg.num_heads * seq * seq * 4
    return per


def max_batch(cfg, m: MachineParams, seq: int, *, intra_ckpt: bool,
              materialize_probs: bool = False) -> int:
    """Largest per-pass batch whose recovered activations + one layer of
    params/grads fit in GPU memory."""
    layer_bytes = cfg.layer_params(0) * BYTES
    # 3 param buffers (compute + 2 prefetch) + f32 grads + 10% reserve
    resident = 3 * layer_bytes + 2 * layer_bytes + 0.1 * m.gpu_mem
    per = act_working_set_per_sample(cfg, seq, intra_ckpt=intra_ckpt,
                                     materialize_probs=materialize_probs)
    return max(1, int((m.gpu_mem - resident) // per))


def run(rep: Optional[Reporter] = None, seq: int = 2048) -> None:
    rep = rep or Reporter()
    rep.section("fig4: single fwd-bwd batch scaling (GPT-65B, A100)")
    cfg = get_config("gpt-65b")
    m = A100_CLOUD

    b_layer = max_batch(cfg, m, seq, intra_ckpt=False)
    b_intra = max_batch(cfg, m, seq, intra_ckpt=True)
    rep.add("fig4/max_batch_per_layer_ckpt", b_layer, "per-layer ckpt only")
    rep.add("fig4/max_batch_intra_ckpt", b_intra,
            f"attn/FFN ckpt ({b_intra / b_layer:.2f}x batch)")

    # checkpoint swap traffic at each schedule's max batch
    cs_layer = tr.checkpoint_bytes(cfg, b_layer, seq)
    cs_intra = 2 * tr.checkpoint_bytes(cfg, b_intra, seq)  # 2 ckpts/layer
    rep.add("fig4/ckpt_traffic_layer_GB", f"{2 * cs_layer / 1e9:.1f}",
            "write+read per iteration")
    rep.add("fig4/ckpt_traffic_intra_GB", f"{2 * cs_intra / 1e9:.1f}",
            f"{cs_intra / cs_layer:.2f}x inflation for "
            f"{b_intra / b_layer:.2f}x batch")

    # can either reach optimizer-I/O saturation? iteration must be long
    # enough to hide the optimizer-state SSD round trip.
    w = Workload.from_config(cfg, micro_batch=1, seq_len=seq)
    t_opt_io = 2 * w.os_bytes / min(m.ssd_read_bw, m.ssd_write_bw)
    for name, b in (("layer", b_layer), ("intra", b_intra)):
        wb = Workload.from_config(cfg, micro_batch=b, seq_len=seq)
        t_comp = 4 * wb.flops_per_mb / m.gpu_flops
        rep.add(f"fig4/compute_vs_optio_{name}",
                f"{t_comp / t_opt_io:.2f}",
                f"compute covers {100 * t_comp / t_opt_io:.0f}% of opt I/O "
                f"at max batch {b} (needs >=1.0 to saturate)")


if __name__ == "__main__":
    run()
