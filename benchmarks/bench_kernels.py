"""Kernel microbenchmarks: Pallas kernels (interpret mode on this CPU
container) validated against the jnp oracles, plus timing of the jitted
oracle path (the number that is meaningful on CPU).

On a real TPU set REPRO_PALLAS_COMPILE=1 and the same entry points give
compiled-kernel timings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, time_call
from repro.kernels import ops, ref


def run(rep: Optional[Reporter] = None) -> None:
    rep = rep or Reporter()
    rep.section("kernels: interpret-mode allclose + jnp-oracle timing")
    key = jax.random.PRNGKey(0)

    # flash attention
    B, H, S, hd = 1, 4, 256, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (B, H, S, hd), jnp.float32) for i in range(3))
    o_k = ops.flash_attention_op(q, k, v, causal=True)
    o_r = ref.ref_attention(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    t = time_call(jax.jit(lambda a, b, c: ref.ref_attention(a, b, c)), q, k, v)
    rep.add("kernels/flash_attention_maxerr", f"{err:.2e}",
            f"(B,H,S,hd)=({B},{H},{S},{hd}); oracle {t * 1e3:.1f} ms")

    # selective scan
    B2, S2, di, st = 2, 128, 64, 8
    x = jax.random.normal(jax.random.fold_in(key, 10), (B2, S2, di))
    dt = jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 11), (B2, S2, di))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 12), (di, st)))
    Bc = jax.random.normal(jax.random.fold_in(key, 13), (B2, S2, st))
    Cc = jax.random.normal(jax.random.fold_in(key, 14), (B2, S2, st))
    D = jax.random.normal(jax.random.fold_in(key, 15), (di,))
    y_k, _ = ops.selective_scan_op(x, dt, A, Bc, Cc, D)
    y_r, _ = ref.ref_selective_scan(x, dt, A, Bc, Cc, D)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    rep.add("kernels/selective_scan_maxerr", f"{err:.2e}",
            f"(B,S,di,st)=({B2},{S2},{di},{st})")

    # fused adam (the paper's cpu_adam hot spot, incl. partial update)
    n = 1 << 14
    p = jax.random.normal(jax.random.fold_in(key, 20), (n,))
    g = jax.random.normal(jax.random.fold_in(key, 21), (n,))
    m = jnp.zeros((n,))
    vv = jnp.zeros((n,))
    p_k, m_k, v_k, lowp = ops.fused_adam_op(p, m, vv, g,
                                            jnp.asarray(1, jnp.int32))
    p_r, m_r, v_r = ref.ref_adam(p, m, vv, g, 1)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in ((p_k, p_r), (m_k, m_r), (v_k, v_r)))
    t = time_call(jax.jit(lambda *a: ref.ref_adam(*a, 1)), p, m, vv, g)
    rep.add("kernels/fused_adam_maxerr", f"{err:.2e}",
            f"n={n}; oracle {t * 1e6:.0f} us "
            f"({n * 4 * 4 / t / 1e9:.1f} GB/s state bw)")


if __name__ == "__main__":
    run()
