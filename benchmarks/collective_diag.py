"""Diagnose collective traffic of one (arch, shape, mesh) pair: group
trip-weighted collective bytes by (kind, result type) to find the
dominant source. Used by the §Perf hillclimbing loop.

  PYTHONPATH=src python -m benchmarks.collective_diag qwen3-4b train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import sys  # noqa: E402
from collections import defaultdict  # noqa: E402


def diag(arch: str, shape_name: str, multi_pod: bool = False,
         top: int = 14, schedule: str = "vertical", fsdp_batch: bool = False):
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch import dryrun, hlo_cost
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train":
        lowered = dryrun.lower_train(cfg, mesh, shape, schedule=schedule,
                                     microbatches=8, fsdp_batch=fsdp_batch)
    elif shape.kind == "prefill":
        lowered = dryrun.lower_prefill(cfg, mesh, shape)
    else:
        lowered = dryrun.lower_decode(cfg, mesh, shape)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    comps = hlo_cost.parse_computations(hlo)
    weights = hlo_cost.computation_weights(comps)
    table = hlo_cost._symbol_table(comps, hlo)

    rows = []
    for cname, instrs in comps.items():
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        for ins in instrs:
            base = ins.op.replace("-start", "")
            if base in hlo_cost.COLL_KINDS and not ins.op.endswith("-done"):
                rb = hlo_cost._types_bytes(ins.result)
                ob = sum(hlo_cost._types_bytes(table.get((cname, s), ""))
                         for s in hlo_cost._operands(ins))
                meta = ""
                i = ins.rest.find("op_name=")
                if i >= 0:
                    meta = ins.rest[i + 9:i + 150].split('"')[0]
                rows.append((w * (rb + ob), w, base, ins.result[:70], meta))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}: "
          f"total collective bytes/dev = {total / 1e9:.2f} GB")
    for b, w, kind, res, meta in rows[:top]:
        print(f"  {b / 1e9:9.3f} GB  w={w:7.0f}  {kind:18s} {res:64s} {meta[:90]}")
    return rows


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-4b"
    shp = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    diag(arch, shp, fsdp_batch="--fsdp" in sys.argv)
