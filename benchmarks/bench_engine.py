"""Measured wall-clock of the REAL offload engine on this container:
vertical vs horizontal schedule, same model / batch / storage split.

This is the system-level counterpart of Fig. 10 that actually runs here
(file-backed SSD tier, threaded prefetch + CPU-Adam overlap). Absolute
numbers reflect this container's CPU; the vertical/horizontal ratio is
the paper's effect, reproduced with real I/O.
"""
from __future__ import annotations

import tempfile
import time
from typing import Optional

import jax

from benchmarks.common import Reporter
from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.offload import OffloadConfig, OffloadEngine


def _measure(cfg, sched: str, M: int, mb: int, s: int, alpha: float,
             ratios: StorageRatios, iters: int = 3) -> dict:
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(cfg, OffloadConfig(
            schedule=sched, num_microbatches=M, micro_batch=mb, seq_len=s,
            alpha=alpha, ratios=ratios), jax.random.PRNGKey(0), d)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        eng.train_step(data.batch(M * mb, s))  # compile warm-up
        eng.meter.reset()
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.train_step(data.batch(M * mb, s))
        eng.finish()
        dt = (time.perf_counter() - t0) / iters
        traffic = sum(eng.meter.snapshot().values())
        eng.close()
    return {"s_per_iter": dt, "traffic_bytes_per_iter": traffic / iters}


def run(rep: Optional[Reporter] = None) -> None:
    rep = rep or Reporter()
    rep.section("engine: measured vertical vs horizontal "
                "(gpt-100m, real 3-tier I/O)")
    cfg = get_config("gpt-100m")
    # I/O-heavy regime: params + opt states fully on "SSD", checkpoints in
    # CPU; 8 micro-batches so horizontal's 2M param reloads + (2M-1) grad
    # swaps dominate. (On this CPU container compute is much slower than
    # on an A100, so the paper's wall-clock gap is compressed — the
    # traffic ratio is the schedule-level effect.)
    M, mb, s = 8, 1, 128
    ratios = StorageRatios(1.0, 0.0, 0.0)
    res = {}
    for sched in ("horizontal", "vertical"):
        r = _measure(cfg, sched, M, mb, s, alpha=0.0, ratios=ratios)
        res[sched] = r
        rep.add(f"engine/{sched}_s_per_iter", f"{r['s_per_iter']:.3f}",
                f"traffic {r['traffic_bytes_per_iter'] / 1e9:.2f} GB/iter")
    sp = res["horizontal"]["s_per_iter"] / res["vertical"]["s_per_iter"]
    tr = res["horizontal"]["traffic_bytes_per_iter"] / \
        res["vertical"]["traffic_bytes_per_iter"]
    rep.add("engine/vertical_speedup", f"{sp:.2f}",
            f"wall-clock; traffic reduced {tr:.2f}x")
    rv = _measure(cfg, "vertical", M, mb, s, alpha=0.3, ratios=ratios)
    rep.add("engine/vertical_alpha0.3_s_per_iter",
            f"{rv['s_per_iter']:.3f}", "with delayed optimizer step")


if __name__ == "__main__":
    run()
