"""Measured wall-clock + traffic of the REAL offload engine on this
container: vertical vs horizontal schedule, the wave hybrid's
ckpt-traffic / param-reuse interpolation, and the activation-policy
(recompute vs SSDTrain-style spill) axis.

This is the system-level counterpart of Fig. 10 that actually runs here
(file-backed SSD tier, threaded prefetch + CPU-Adam overlap). Absolute
numbers reflect this container's CPU; the vertical/horizontal ratio is
the paper's effect, reproduced with real I/O. All schedules are
compiled ``repro.core.plan`` plans walked by the one executor.

    PYTHONPATH=src python benchmarks/bench_engine.py
        [--schedule all|vertical|horizontal|wave] [--smoke] [--json OUT]
        [--trace-dir DIR]

``--smoke --json OUT`` runs the CI bench-smoke battery — all three
schedules x activation policy on the tiny config, plus the paced-SSD
cross-stream-lookahead A/B (interleaved engines at prefetch depth 2 vs
0, α>0, 2 striped paths with both SSD routes token-bucket-capped) and
the online-autotuner recovery A/B (an engine hand-tuned for a
mis-specified machine vs the same start plus an ``AutotuneController``
that must measure, re-solve, and swap its way back to the hand-tuned
plan) and the heterogeneous-path placement A/B (static ``i % P``
striping vs backlog-aware chunk placement on a 2-path device whose
per-path token buckets sit at a 4:1 rate split, with per-path achieved
rates and the ``obs.reconcile`` byte-conservation flag in the cells)
and the continuous-batching serve smoke (a ``repro.serve.ServeEngine``
on the paced 2-path device: >= 2 concurrent requests under a KV budget
below the total KV footprint, a mid-generation preempt/resume round
trip, and the three-way KV byte invariant as the ``serve_ok`` boolean
gate) and the degraded-mode A/B (training under seeded transient
chaos with integrity + retry on, bitwise vs a fault-free twin as the
``chaos_bitwise_ok`` gate; plus an SSD streaming workload that loses
one of two equal-cap paths mid-run — write failover to the survivor
as the ``failover_ok`` gate with the degraded/healthy throughput
ratio floored at ``DEGRADED_FLOOR_GATE``) — and dumps per-cell
throughput, stall-seconds, prefetch
hit-rate, and the top stall stream (from ``metrics_snapshot()``) for
``check_smoke.py`` to gate against the checked-in
``baseline_smoke.json``.

``--trace-dir DIR`` additionally exports one Chrome trace-event JSON
per cell (Perfetto-loadable; uploaded as a CI artifact). The measured
iterations of the schedules x policy cells keep tracing DISABLED —
that is the regime the ±20% throughput gate protects — and their
artifacts come from one traced iteration each in a separate pass
AFTER all measurement (a traced iteration's writeback otherwise
bleeds into the next cell's measured window). The lookahead A/B
measures with tracing ENABLED on both engines: its speedup gate
doubles as the tracing-overhead acceptance check.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time
from typing import Optional

import jax

try:
    from benchmarks.common import Reporter
    from benchmarks.check_smoke import (AUTOTUNE_RECOVERY_GATE,
                                        DEGRADED_FLOOR_GATE,
                                        LOOKAHEAD_GAIN_GATE,
                                        PATH_PLACEMENT_GAIN_GATE)
except ImportError:     # run directly as a script: benchmarks/ not a pkg
    sys.path.insert(0, os.path.dirname(__file__))
    from common import Reporter
    from check_smoke import (AUTOTUNE_RECOVERY_GATE, DEGRADED_FLOOR_GATE,
                             LOOKAHEAD_GAIN_GATE,
                             PATH_PLACEMENT_GAIN_GATE)
from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.offload import OffloadConfig, OffloadEngine


def _measure(cfg, sched: str, M: int, mb: int, s: int, alpha: float,
             ratios: StorageRatios, iters: int = 3,
             wave_size: int = 0, act_policy: str = "recompute",
             io=None, prefetch_depth: int = 1) -> dict:
    from repro.obs import top_stall_stream

    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(cfg, OffloadConfig(
            schedule=sched, num_microbatches=M, micro_batch=mb, seq_len=s,
            alpha=alpha, ratios=ratios, wave_size=wave_size,
            activation_policy=act_policy, io=io,
            prefetch_depth=prefetch_depth),
            jax.random.PRNGKey(0), d)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        eng.train_step(data.batch(M * mb, s))  # compile warm-up
        eng.meter.reset()
        eng.reset_stats()
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.train_step(data.batch(M * mb, s))
        eng.finish()
        dt = (time.perf_counter() - t0) / iters
        routes = dict(eng.meter.bytes)
        traffic = sum(routes.values())
        snap = eng.metrics_snapshot()
        look = snap["lookahead"]
        eng.close()

    def per_iter(cat):
        return sum(v for (c, r), v in routes.items() if c == cat) / iters

    return {"s_per_iter": dt, "traffic_bytes_per_iter": traffic / iters,
            "tokens_per_s": M * mb * s / dt,
            "param_bytes_per_iter": per_iter("param"),
            "ckpt_bytes_per_iter": per_iter("ckpt"),
            "inter_grad_bytes_per_iter": per_iter("inter_grad"),
            "act_bytes_per_iter": per_iter("act"),
            "grad_bytes_per_iter": per_iter("grad"),
            "stall_s_per_iter": look["stall_s"] / iters,
            "prefetch_hit_rate": look["hit_rate"],
            "top_stall_stream": top_stall_stream(snap["op_seconds"])}


def _export_cell_trace(cfg, sched: str, M: int, mb: int, s: int,
                       alpha: float, ratios: StorageRatios,
                       wave_size: int, act_policy: str,
                       trace_path: str) -> None:
    """One traced iteration of a smoke cell, exported as Chrome
    trace-event JSON. Runs in its own engine, AFTER every measured
    window — a traced iteration's disk writeback bleeds into the next
    cell's 1-iteration measurement, so the artifacts are produced in a
    separate pass instead of inline with the gate numbers."""
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(cfg, OffloadConfig(
            schedule=sched, num_microbatches=M, micro_batch=mb, seq_len=s,
            alpha=alpha, ratios=ratios, wave_size=wave_size,
            activation_policy=act_policy), jax.random.PRNGKey(0), d)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        eng.train_step(data.batch(M * mb, s))  # compile warm-up
        eng.tracer.clear()
        eng.tracer.enable()
        eng.train_step(data.batch(M * mb, s))
        eng.finish()
        eng.tracer.export_chrome(trace_path)
        eng.close()


#: the paced-SSD regime for the lookahead A/B: two striped paths with
#: token-bucket caps on BOTH SSD routes, far below this container's
#:  page cache. The lookahead's wall-clock win here is the one the
#: paper's α-overlap and MLP-Offload's idle-concurrent-level lesson
#: predict: hints + the epilogue seam keep read and write backlogs
#: coexisting across the path channels (both buckets draining at
#: once), where the hint-free prologue executor phase-separates them
#: and serializes the two caps.
PACED_BANDWIDTH = {"ssd->cpu": 0.125e9, "cpu->ssd": 0.125e9}
PACED_ALPHA = 0.75
PACED_AB_ITERS = 3
# the A/B acceptance floor (LOOKAHEAD_GAIN_GATE, imported above) is
# owned by check_smoke.py — the tool that actually gates it — so the
# bench report can never document a threshold the gate stopped
# enforcing; measured 1.24-1.45x on the dev container. The gate lives
# in the gating tool so a loaded runner degrades to a CI failure with
# the full comparison table, never a crashed bench or --update run.


def run_lookahead_ab(rep: Optional[Reporter] = None,
                     trace_dir: str = "") -> dict:
    """The paced-SSD cross-stream-lookahead A/B (the PR-acceptance
    datapoint): identical engines at ``prefetch_depth=2`` (hints + the
    cross-iteration α-tail seam) vs ``prefetch_depth=0`` (no hints,
    pre-lookahead prologue ordering), α>0, everything on the paced SSD
    tier. Iterations are INTERLEAVED between the two engines so
    machine drift cancels out of the ratio — and both run with span
    tracing ENABLED, so the speedup gate doubles as the
    tracing-overhead acceptance check. Returns the two cells keyed
    ``paced_alpha_lookahead`` / ``paced_alpha_nolookahead``."""
    import numpy as np

    from repro.io import IOConfig
    from repro.obs import top_stall_stream

    rep = rep or Reporter()
    cfg, M, mb, s = get_config("gpt-tiny"), 4, 1, 64
    rep.section(f"bench-smoke: paced-SSD lookahead A/B (alpha="
                f"{PACED_ALPHA}, 2 paths, caps {PACED_BANDWIDTH})")

    def build(root, depth):
        paths = [os.path.join(root, "p0"), os.path.join(root, "p1")]
        return OffloadEngine(cfg, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=mb,
            seq_len=s, alpha=PACED_ALPHA,
            ratios=StorageRatios(0.0, 0.0, 0.0),
            io=IOConfig(paths=paths, bandwidth=dict(PACED_BANDWIDTH)),
            prefetch_depth=depth), jax.random.PRNGKey(0), root)

    cells = {}
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        e_la, e_nl = build(d1, 2), build(d2, 0)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        for e in (e_la, e_nl):
            e.train_step(data.batch(M * mb, s))     # compile warm-up
            e.meter.reset()
            e.reset_stats()
            e.tracer.clear()
            e.tracer.enable()       # the A/B measures WITH tracing on
        t = {"la": 0.0, "nl": 0.0}
        for _ in range(PACED_AB_ITERS):
            batch = data.batch(M * mb, s)
            for key, e in (("la", e_la), ("nl", e_nl)):
                t0 = time.perf_counter()
                e.train_step(batch)
                t[key] += time.perf_counter() - t0
        for e in (e_la, e_nl):
            e.finish()
        for key, name, e in (("la", "paced_alpha_lookahead", e_la),
                             ("nl", "paced_alpha_nolookahead", e_nl)):
            snap = e.metrics_snapshot()
            look = snap["lookahead"]
            dt = t[key] / PACED_AB_ITERS
            cells[name] = {
                "s_per_iter": dt,
                "tokens_per_s": M * mb * s / dt,
                "stall_s_per_iter": look["stall_s"] / PACED_AB_ITERS,
                "prefetch_hit_rate": look["hit_rate"],
                "hint_skips": look["hint_skips"],
                "top_stall_stream": top_stall_stream(snap["op_seconds"]),
            }
            if trace_dir:
                e.tracer.export_chrome(
                    os.path.join(trace_dir, f"{name}.trace.json"))
            rep.add(f"smoke/{name}_tokens_per_s",
                    f"{cells[name]['tokens_per_s']:.0f}",
                    f"stall {cells[name]['stall_s_per_iter']:.3f} s/iter, "
                    f"hit rate {cells[name]['prefetch_hit_rate']:.2f}")
        # the lookahead engine never recomputes spuriously
        assert np.isfinite(t["la"]) and np.isfinite(t["nl"])
        e_la.close()
        e_nl.close()
    la, nl = (cells["paced_alpha_lookahead"],
              cells["paced_alpha_nolookahead"])
    gain = la["tokens_per_s"] / nl["tokens_per_s"]
    rep.add("smoke/lookahead_speedup", f"{gain:.2f}x",
            f"stall {nl['stall_s_per_iter']:.3f} -> "
            f"{la['stall_s_per_iter']:.3f} s/iter "
            f"(check_smoke gates this at >= {LOOKAHEAD_GAIN_GATE}x)")
    return cells


#: the heterogeneous-path regime for the placement A/B: two striped
#: paths with PER-PATH token buckets at a 4:1 rate split and NO route
#: caps — the device the autotuner's ``path_policy`` axis exists for.
#: Static ``i % P`` striping puts half the chunk bytes on the slow
#: path, so its roofline is 2x the slow cap (0.05 GB/s here); backlog
#: placement weights the fast path 4:1 and drains toward sum-of-caps
#: (0.125 GB/s) — the same split ``machine_for_path_policy`` prices
#: for the LP. The small chunk size keeps every gpt-tiny layer blob
#: many full chunks long, so placement has real freedom per write.
PATH_AB_CAPS = (0.1e9, 0.025e9)
PATH_AB_CHUNK = 256 << 10


def run_path_ab(rep: Optional[Reporter] = None,
                trace_dir: str = "") -> dict:
    """The heterogeneous-path placement A/B (the PR-acceptance
    datapoint): identical engines on a 2-path device with per-path
    token buckets at a 4:1 rate split, one pinned to the static
    ``i % P`` layout, one scheduling every full-chunk write with
    ``path_policy="backlog"``. Iterations are INTERLEAVED so machine
    drift cancels out of the ratio, and both engines measure with span
    tracing ENABLED — the per-path achieved rates in the cells come
    from the tracer, and each cell's ``path_sum_ok`` asserts the
    ``obs.reconcile`` conservation check (per-path chunk meters sum
    byte-exactly to route totals). Returns cells keyed
    ``paced_path_static`` / ``paced_path_backlog``."""
    from repro.io import IOConfig
    from repro.obs import reconcile, top_stall_stream

    rep = rep or Reporter()
    cfg, M, mb, s = get_config("gpt-tiny"), 4, 1, 64
    rep.section(f"bench-smoke: heterogeneous-path placement A/B (alpha="
                f"{PACED_ALPHA}, 2 paths, per-path caps {PATH_AB_CAPS})")

    def build(root, policy):
        paths = [os.path.join(root, "p0"), os.path.join(root, "p1")]
        return OffloadEngine(cfg, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=mb,
            seq_len=s, alpha=PACED_ALPHA,
            ratios=StorageRatios(0.0, 0.0, 0.0),
            io=IOConfig(paths=paths, chunk_bytes=PATH_AB_CHUNK,
                        path_bandwidth=PATH_AB_CAPS, path_policy=policy),
            prefetch_depth=2), jax.random.PRNGKey(0), root)

    cells = {}
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        e_st, e_bl = build(d1, "static"), build(d2, "backlog")
        data = SyntheticLM(cfg.vocab_size, seed=0)
        for e in (e_st, e_bl):
            e.train_step(data.batch(M * mb, s))     # compile warm-up
            e.finish()          # flush the warm-up alpha-tail so the
            e.meter.reset()     # measured window reconciles byte-exact
            e.reset_stats()
            e.tracer.clear()
            e.tracer.enable()   # per-path rates come from the tracer
        t = {"st": 0.0, "bl": 0.0}
        for _ in range(PACED_AB_ITERS):
            batch = data.batch(M * mb, s)
            for key, e in (("st", e_st), ("bl", e_bl)):
                t0 = time.perf_counter()
                e.train_step(batch)
                t[key] += time.perf_counter() - t0
        for e in (e_st, e_bl):
            e.finish()
        for key, name, e in (("st", "paced_path_static", e_st),
                             ("bl", "paced_path_backlog", e_bl)):
            snap = e.metrics_snapshot()
            look = snap["lookahead"]
            rec = reconcile(e.plan, snap, steps=PACED_AB_ITERS)
            assert not rec.path_sum_mismatches, rec.format()
            routes = (snap.get("trace") or {}).get("routes", {})
            per_path = {
                route: {p: {"bytes": d["bytes"],
                            "rate_bps": d["rate_bps"]}
                        for p, d in routes[route]["per_path"].items()}
                for route in ("ssd->cpu", "cpu->ssd") if route in routes}
            dt = t[key] / PACED_AB_ITERS
            cells[name] = {
                "s_per_iter": dt,
                "tokens_per_s": M * mb * s / dt,
                "stall_s_per_iter": look["stall_s"] / PACED_AB_ITERS,
                "prefetch_hit_rate": look["hit_rate"],
                "top_stall_stream": top_stall_stream(snap["op_seconds"]),
                "per_path": per_path,
                "path_sum_ok": not rec.path_sum_mismatches,
            }
            if trace_dir:
                e.tracer.export_chrome(
                    os.path.join(trace_dir, f"{name}.trace.json"))
            split = {route: [d["bytes"] for _, d in sorted(pp.items())]
                     for route, pp in per_path.items()}
            rep.add(f"smoke/{name}_tokens_per_s",
                    f"{cells[name]['tokens_per_s']:.0f}",
                    f"per-path bytes {split}, "
                    f"stall {cells[name]['stall_s_per_iter']:.3f} s/iter")
        e_st.close()
        e_bl.close()
    st, bl = cells["paced_path_static"], cells["paced_path_backlog"]
    gain = bl["tokens_per_s"] / st["tokens_per_s"]
    rep.add("smoke/path_placement_speedup", f"{gain:.2f}x",
            f"stall {st['stall_s_per_iter']:.3f} -> "
            f"{bl['stall_s_per_iter']:.3f} s/iter "
            f"(check_smoke gates this at >= {PATH_PLACEMENT_GAIN_GATE}x)")
    return cells


def run_serve_smoke(rep: Optional[Reporter] = None,
                    trace_dir: str = "") -> dict:
    """The continuous-batching serve smoke (the PR-acceptance
    datapoint): a ``repro.serve.ServeEngine`` on the paced 2-path
    device (per-path token buckets at the 4:1 ``PATH_AB_CAPS`` split,
    backlog placement), serving more requests than the KV budget holds
    at once — so admission queues, >= 2 requests run concurrently, and
    an explicit mid-generation preempt exercises the full
    SPILL_KV -> tiers -> FETCH_KV round trip. The cell carries decode
    tokens/s (gated against the baseline like every cell), the KV tier
    hit-rate (warm fraction of fetched KV bytes, informational), and
    ``serve_ok`` — the three-way byte invariant (per-step
    ``plan_traffic`` predictions == measured meters ==
    ``traffic.kv_traffic`` closed form), gated as a boolean like
    ``path_sum_ok``."""
    import numpy as np

    import jax.numpy as jnp

    from repro.core.traffic import kv_blocks, kv_traffic
    from repro.io import IOConfig
    from repro.models import model as mdl
    from repro.serve import ServeConfig, ServeEngine

    rep = rep or Reporter()
    cfg = get_config("gpt-tiny")
    n_req, prompt_len, gen, max_len, bb = 4, 6, 6, 16, 4096
    rep.section(f"bench-smoke: continuous-batching serve ({cfg.name}, "
                f"{n_req} requests, paced 2-path caps {PATH_AB_CAPS})")
    with tempfile.TemporaryDirectory() as root:
        paths = [os.path.join(root, "p0"), os.path.join(root, "p1")]
        template = mdl.init_caches(cfg, 1, max_len, dtype=jnp.float32)
        bpr = sum(kv_blocks(nb, bb)
                  for nb in mdl.cache_unit_nbytes(cfg, template))
        scfg = ServeConfig(
            max_len=max_len, kv_block_bytes=bb,
            kv_budget_bytes=2 * bpr * bb,       # half the submitted load
            io=IOConfig(paths=paths, chunk_bytes=PATH_AB_CHUNK,
                        path_bandwidth=PATH_AB_CAPS,
                        path_policy="backlog"),
            trace=bool(trace_dir))
        eng = ServeEngine(cfg, scfg, jax.random.PRNGKey(0), root)
        rng = np.random.default_rng(0)
        prompts = [[int(t) for t in
                    rng.integers(0, cfg.vocab_size, prompt_len)]
                   for _ in range(n_req)]
        # compile warm-up on a throwaway request, then reset the timed
        # counters (NOT the byte meters — the invariant is cumulative)
        warm = eng.submit(prompts[0], 2)
        while eng.pending():
            eng.step()
        assert len(eng.result(warm)) == 2
        eng.phase_time.clear()
        eng.tokens_decoded = 0

        rids = [eng.submit(p, gen) for p in prompts]
        assert eng.capacity_blocks < n_req * eng.blocks_per_request
        eng.step()
        eng.preempt(next(r for r in rids
                         if eng.requests[r].state == "running"))
        max_conc, steps = 0, 1
        while eng.pending():
            eng.step()
            steps += 1
            max_conc = max(max_conc, sum(
                1 for r in eng.requests.values() if r.state == "running"))
            assert steps < 200, "serve smoke did not converge"
        assert max_conc >= 2, f"only {max_conc} concurrent request(s)"
        assert all(len(eng.result(r)) == gen for r in rids)

        measured = {k: int(v) for k, v in eng.meter.bytes.items()}
        predicted = {k: int(v) for k, v in eng.predicted_traffic.items()}
        kt = kv_traffic(eng.kv_unit_nbytes, bb, scfg.kv_x_host,
                        eng.kv_spills, eng.kv_fetches)
        serve_ok = all(
            measured.get(k, 0) == predicted.get(k, 0)
            for k in set(measured) | set(predicted)) and \
            measured.get(("kv", "gpu->cpu"), 0) == kt.spill and \
            measured.get(("kv", "cpu->ssd"), 0) == kt.ssd_spill and \
            measured.get(("kv", "cpu->gpu"), 0) == kt.fetch and \
            measured.get(("kv", "ssd->cpu"), 0) == kt.ssd_fetch
        snap = eng.metrics_snapshot()
        decode_s = max(eng.phase_time.get("decode", 0.0), 1e-9)
        cell = {
            "tokens_per_s": eng.tokens_decoded / decode_s,
            "kv_hit_rate": snap["kv"]["hit_rate"],
            "serve_ok": bool(serve_ok),
            "max_concurrent": max_conc,
            "preempted": int(eng.preempted),
            "steps": steps,
            "kv_bytes": sum(v for (c, _), v in eng.meter.bytes.items()
                            if c == "kv"),
        }
        if trace_dir:
            eng.tracer.export_chrome(
                os.path.join(trace_dir, "serve_paced_2path.trace.json"))
        eng.close()
    rep.add("smoke/serve_paced_2path_tokens_per_s",
            f"{cell['tokens_per_s']:.0f}",
            f"decode; kv hit-rate {cell['kv_hit_rate']:.2f}, "
            f"{cell['max_concurrent']} concurrent, "
            f"3-way bytes {'exact' if cell['serve_ok'] else 'MISMATCH'}")
    return {"serve_paced_2path": cell}


#: the degraded-mode regime: equal per-path caps so killing either
#: path halves the device's aggregate roofline — the measured
#: degraded/healthy ratio lands near 0.5 and ``check_smoke`` gates it
#: (``DEGRADED_FLOOR_GATE``) together with the failover booleans. The
#: caps sit far below the streaming workload's software floor (chunk
#: bookkeeping + CRC sidecar upkeep run tens of MB/s on this
#: container), so the token buckets — not Python — set the roofline
#: and the kill actually halves it.
DEGRADED_CAPS = (4e6, 4e6)


def run_degraded_ab(rep: Optional[Reporter] = None,
                    trace_dir: str = "") -> dict:
    """The degraded-mode A/B (the resilience PR-acceptance datapoint),
    two cells:

    * ``paced_degraded_chaos`` — a training run on the paced 2-path
      device with TRANSIENT chaos (seeded EAGAIN + latency spikes from
      :class:`repro.io.chaos.ChaosSpec`) on every chunk op, integrity
      verification on, bounded retries absorbing the faults.
      Iterations INTERLEAVE with a fault-free twin so machine drift
      cancels; the cell's ``chaos_bitwise_ok`` boolean asserts the
      chaotic losses equal the clean ones bit for bit, and its
      tokens/s is gated against the baseline like any cell.
    * ``paced_degraded_pathkill`` — an SSD streaming workload (host
      buffers stay authoritative, like the optimizer writeback) on a
      2-path device with EQUAL per-path caps; one path is killed
      mid-run. ``failover_ok`` asserts every post-kill overwrite
      re-placed onto the survivor and read back bitwise with
      ``chunk_failovers > 0`` and no budget leak; the
      degraded/healthy throughput ratio is gated at
      ``DEGRADED_FLOOR_GATE`` (the survivor holds half the aggregate
      caps, so ~0.5 when failover works, ~0 when it wedges).
    """
    import numpy as np

    from repro.io import ChaosSpec, IOConfig, IOEngine, install_chaos
    from repro.offload.stores import SSDStore, TrafficMeter

    rep = rep or Reporter()
    cells = {}

    # ---- cell 1: transient chaos on a paced training run ----
    cfg, M, mb, s = get_config("gpt-tiny"), 4, 1, 64
    rep.section(f"bench-smoke: degraded-mode A/B (transient chaos + "
                f"mid-run path kill, caps {PACED_BANDWIDTH})")

    def build(root):
        paths = [os.path.join(root, "p0"), os.path.join(root, "p1")]
        return OffloadEngine(cfg, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=mb,
            seq_len=s, alpha=PACED_ALPHA,
            ratios=StorageRatios(0.0, 0.0, 0.0),
            io=IOConfig(paths=paths, bandwidth=dict(PACED_BANDWIDTH),
                        retries=5, integrity=True),
            prefetch_depth=2), jax.random.PRNGKey(0), root)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        e_cl, e_ch = build(d1), build(d2)
        chaos = install_chaos(e_ch.ssd, ChaosSpec(
            error_rate=0.05, latency_rate=0.05, latency_s=0.0005,
            seed=11))
        data = SyntheticLM(cfg.vocab_size, seed=0)
        warm = data.batch(M * mb, s)    # SHARED: the twins must see
        for e in (e_cl, e_ch):          # identical data to stay bitwise
            e.train_step(warm)          # compile warm-up
            e.meter.reset()
            e.reset_stats()
            e.tracer.clear()
            e.tracer.enable()   # like the lookahead A/B: measure traced
        t = {"cl": 0.0, "ch": 0.0}
        losses = {"cl": [], "ch": []}
        for _ in range(PACED_AB_ITERS):
            batch = data.batch(M * mb, s)
            for key, e in (("cl", e_cl), ("ch", e_ch)):
                t0 = time.perf_counter()
                losses[key].append(e.train_step(batch))
                t[key] += time.perf_counter() - t0
        for e in (e_cl, e_ch):
            e.finish()
        snap = e_ch.ioe.metrics_snapshot()
        dt = t["ch"] / PACED_AB_ITERS
        ok = losses["ch"] == losses["cl"]
        cells["paced_degraded_chaos"] = {
            "s_per_iter": dt,
            "tokens_per_s": M * mb * s / dt,
            "chaos_bitwise_ok": bool(ok),
            "chaos_injected": int(chaos.injected["transient"]),
            "chunk_retries": int(snap["chunk_retries"]),
            "clean_tokens_per_s": M * mb * s / (t["cl"] / PACED_AB_ITERS),
        }
        if trace_dir:
            e_ch.tracer.export_chrome(os.path.join(
                trace_dir, "paced_degraded_chaos.trace.json"))
        e_cl.close()
        e_ch.close()
    c = cells["paced_degraded_chaos"]
    rep.add("smoke/degraded_chaos_tokens_per_s",
            f"{c['tokens_per_s']:.0f}",
            f"{c['chaos_injected']} transients injected, "
            f"{c['chunk_retries']} retries, losses "
            f"{'bitwise OK' if c['chaos_bitwise_ok'] else 'DIVERGED'} "
            f"vs clean {c['clean_tokens_per_s']:.0f} tok/s")

    # ---- cell 2: one path killed mid-run, writes fail over ----
    n_t, t_mb, passes = 2, 2, 2
    with tempfile.TemporaryDirectory() as root:
        paths = [os.path.join(root, f"p{i}") for i in range(2)]
        ioe = IOEngine(IOConfig(paths=paths, chunk_bytes=PATH_AB_CHUNK,
                                path_bandwidth=DEGRADED_CAPS,
                                path_policy="backlog",
                                retries=2, integrity=True))
        ssd = SSDStore(paths[0], TrafficMeter(), engine=ioe)
        chaos = install_chaos(ssd)
        rng = np.random.default_rng(0)
        bufs = [rng.integers(0, 255, t_mb << 20, dtype=np.uint8)
                for _ in range(n_t)]

        def one_pass(gen):
            ok = True
            for i, base in enumerate(bufs):
                arr = base + np.uint8(gen)          # wraps; host copy is
                ssd.write(f"t{i}", arr, "opt")      # the authority
                ok &= bool(np.array_equal(ssd.read(f"t{i}", "opt"), arr))
            return ok

        t0 = time.perf_counter()
        ok_healthy = all(one_pass(g) for g in range(passes))
        t_healthy = time.perf_counter() - t0
        chaos.kill_path(1)                          # the device dies NOW
        t0 = time.perf_counter()
        ok_degraded = all(one_pass(passes + g) for g in range(passes))
        t_degraded = time.perf_counter() - t0
        snap = ioe.metrics_snapshot()
        window = 2 * n_t * (t_mb << 20) * passes    # write+read bytes
        failover_ok = (ok_healthy and ok_degraded
                       and snap["chunk_failovers"] > 0
                       and snap["inflight_bytes"] == 0)
        cells["paced_degraded_pathkill"] = {
            "healthy_mb_per_s": window / t_healthy / 1e6,
            "degraded_mb_per_s": window / t_degraded / 1e6,
            "degraded_ratio": t_healthy / t_degraded,
            "failover_ok": bool(failover_ok),
            "chunk_failovers": int(snap["chunk_failovers"]),
            "paths_drained": snap["paths_drained"],
        }
        ssd.close()
    c = cells["paced_degraded_pathkill"]
    rep.add("smoke/degraded_pathkill",
            f"{c['degraded_ratio']:.2f}x",
            f"{c['healthy_mb_per_s']:.0f} -> {c['degraded_mb_per_s']:.0f}"
            f" MB/s after the kill; {c['chunk_failovers']} chunk "
            f"failovers, round-trips "
            f"{'bitwise OK' if c['failover_ok'] else 'BROKEN'} "
            f"(check_smoke floors the ratio at {DEGRADED_FLOOR_GATE})")
    return cells


#: the deliberately MIS-SPECIFIED machine the autotune A/B hands its
#: controller: compute and DRAM scaled to the gpt-tiny smoke workload,
#: but the SSD link rates left at the A100-node datasheet numbers
#: (6/3 GB/s) — ~25-50x faster than the paced device below. Under the
#: datasheet rates the LP scores prefetch depth a wash (win ~1.004x),
#: so a hand config of depth 0 is a perfectly reasonable read of this
#: machine; under the LIVE measured ~0.125 GB/s the same LP prefers
#: the lookahead plan by ~1.1x. The gap between those two solves is
#: exactly what the live-rate ingestion fix recovers.
AB_MISSPEC_MACHINE_KW = dict(gpu_flops=5e9, cpu_mem=2.5e7)


def run_autotune_ab(rep: Optional[Reporter] = None,
                    trace_dir: str = "") -> dict:
    """The online-autotuner recovery A/B on the paced 2-path device:
    a HAND-TUNED engine (prefetch depth 2, the knob the lookahead A/B
    proves out) vs an engine started from the mis-specified machine's
    hand config (depth 0) with an ``AutotuneController`` attached.
    The controller gets a short adaptation phase (measured windows +
    ``post_step``), then both engines run ``PACED_AB_ITERS``
    INTERLEAVED timed iterations so machine drift cancels out of the
    ratio. ``check_smoke.py`` gates adaptive/hand-tuned tokens/s at
    ``AUTOTUNE_RECOVERY_GATE`` — the autotuner must claw back the
    throughput the bad machine description gave away. Returns cells
    keyed ``paced_autotune_handtuned`` / ``paced_autotune_adaptive``."""
    from repro.core.perfmodel import MachineParams
    from repro.io import IOConfig
    from repro.offload import AutotuneConfig, AutotuneController

    rep = rep or Reporter()
    cfg, M, mb, s = get_config("gpt-tiny"), 4, 1, 64
    rep.section(f"bench-smoke: paced-SSD autotune recovery A/B (alpha="
                f"{PACED_ALPHA}, 2 paths, caps {PACED_BANDWIDTH})")

    def build(root, depth):
        paths = [os.path.join(root, "p0"), os.path.join(root, "p1")]
        return OffloadEngine(cfg, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=mb,
            seq_len=s, alpha=PACED_ALPHA,
            ratios=StorageRatios(0.0, 0.0, 0.0),
            io=IOConfig(paths=paths, bandwidth=dict(PACED_BANDWIDTH)),
            prefetch_depth=depth), jax.random.PRNGKey(0), root)

    cells = {}
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        e_ht, e_at = build(d1, 2), build(d2, 0)
        ctl = AutotuneController(e_at, AutotuneConfig(
            interval=1, hysteresis=0.05, cooldown=0, max_retunes=1,
            prefetch_depths=(0, 2),
            machine=MachineParams(name="ab-misspec",
                                  **AB_MISSPEC_MACHINE_KW)))
        data = SyntheticLM(cfg.vocab_size, seed=0)
        for e in (e_ht, e_at):
            e.train_step(data.batch(M * mb, s))     # compile warm-up
            e.tracer.enable()
        # --- adaptation phase: measured windows until the swap lands
        # (bounded — a blocked/holding controller just times as-is and
        # fails the recovery gate with its decision log in the cell) ---
        adapt_steps = 0
        ctl._begin_window()         # drop warm-up bytes from window 0
        for _ in range(3):
            e_at.train_step(data.batch(M * mb, s))
            ctl.post_step()
            adapt_steps += 1
            if ctl.retunes:
                break
        adapted_depth = e_at.ocfg.resolved_prefetch_depth()
        # --- interleaved timed window (no further controller windows:
        # the retune budget is spent) ---
        for e in (e_ht, e_at):
            e.meter.reset()
            e.reset_stats()
            e.tracer.clear()
        t = {"ht": 0.0, "at": 0.0}
        for _ in range(PACED_AB_ITERS):
            batch = data.batch(M * mb, s)
            for key, e in (("ht", e_ht), ("at", e_at)):
                t0 = time.perf_counter()
                e.train_step(batch)
                t[key] += time.perf_counter() - t0
        for e in (e_ht, e_at):
            e.finish()
        actions = [dc["action"] for dc in ctl.decisions]
        for key, name, e in (("ht", "paced_autotune_handtuned", e_ht),
                             ("at", "paced_autotune_adaptive", e_at)):
            dt = t[key] / PACED_AB_ITERS
            cells[name] = {
                "s_per_iter": dt,
                "tokens_per_s": M * mb * s / dt,
                "prefetch_depth": e.ocfg.resolved_prefetch_depth(),
            }
            if trace_dir:
                e.tracer.export_chrome(
                    os.path.join(trace_dir, f"{name}.trace.json"))
        cells["paced_autotune_adaptive"].update(
            retunes=ctl.retunes, adapt_steps=adapt_steps,
            decisions=actions)
        e_ht.close()
        e_at.close()
    ht, at = (cells["paced_autotune_handtuned"],
              cells["paced_autotune_adaptive"])
    ratio = at["tokens_per_s"] / ht["tokens_per_s"]
    rep.add("smoke/autotune_recovery", f"{ratio:.2f}x",
            f"adapted depth 0 -> {adapted_depth} in {adapt_steps} "
            f"step(s), decisions {actions} (check_smoke gates this at "
            f">= {AUTOTUNE_RECOVERY_GATE}x)")
    return cells


def run_smoke(rep: Optional[Reporter] = None, json_path: str = "",
              trace_dir: str = "") -> dict:
    """The CI bench-smoke battery: every schedule x activation policy
    on the tiny config, one measured iteration each, plus the paced-SSD
    cross-stream-lookahead A/B (α>0, hints on vs off). The JSON is the
    artifact ``check_smoke.py`` gates (>20% throughput drop — or a
    stall-seconds regression — vs the checked-in baseline fails the
    push) and MLP-Offload-style per-route traffic numbers ride along
    for the archaeology. With ``trace_dir`` every cell also exports a
    Chrome trace-event JSON there (see the module docstring for which
    cells measure traced vs untraced)."""
    rep = rep or Reporter()
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    cfg, M, mb, s = get_config("gpt-tiny"), 4, 1, 64
    ratios = StorageRatios(0.0, 0.0, 0.0)
    rep.section(f"bench-smoke: schedules x activation policy "
                f"({cfg.name}, M={M})")
    cells = {}
    for sched, W in (("vertical", 0), ("horizontal", 0), ("wave", 2)):
        for pol in ("recompute", "spill"):
            key = f"{sched}_{pol}"
            r = _measure(cfg, sched, M, mb, s, alpha=0.0, ratios=ratios,
                         iters=1, wave_size=W, act_policy=pol)
            cells[key] = r
            rep.add(f"smoke/{key}_tokens_per_s", f"{r['tokens_per_s']:.0f}",
                    f"{r['traffic_bytes_per_iter'] / 1e6:.1f} MB/iter, "
                    f"act {r['act_bytes_per_iter'] / 1e6:.2f} MB/iter")
    # structural sanity, cheap enough for every push: the spill cells
    # carry the act stream, the recompute cells none
    for sched in ("vertical", "horizontal", "wave"):
        assert cells[f"{sched}_spill"]["act_bytes_per_iter"] > 0
        assert cells[f"{sched}_recompute"]["act_bytes_per_iter"] == 0

    # --- the paced-SSD lookahead A/B (the PR-acceptance datapoint) ---
    cells.update(run_lookahead_ab(rep, trace_dir=trace_dir))

    # --- the autotune recovery A/B: mis-specified machine, live-rate
    # ingestion, mid-training plan swap (gated by check_smoke) ---
    cells.update(run_autotune_ab(rep, trace_dir=trace_dir))

    # --- the heterogeneous-path placement A/B: static i%P layout vs
    # backlog-aware chunk placement on a 4:1 per-path paced device
    # (gated by check_smoke, with the per-path conservation check) ---
    cells.update(run_path_ab(rep, trace_dir=trace_dir))

    # --- the continuous-batching serve smoke: >= 2 concurrent requests
    # under a KV budget below the total KV footprint on the paced
    # 2-path device, with the three-way KV byte invariant as a boolean
    # gate (serve_ok) next to the decode tokens/s ---
    cells.update(run_serve_smoke(rep, trace_dir=trace_dir))

    # --- the degraded-mode A/B: transient chaos absorbed bitwise by
    # retry (chaos_bitwise_ok), and one path killed mid-run with writes
    # failing over to the survivor (failover_ok + the throughput-floor
    # ratio, all gated by check_smoke) ---
    cells.update(run_degraded_ab(rep, trace_dir=trace_dir))

    # --- trace artifacts for the schedule cells, strictly AFTER every
    # measured window (see _export_cell_trace) ---
    if trace_dir:
        for sched, W in (("vertical", 0), ("horizontal", 0), ("wave", 2)):
            for pol in ("recompute", "spill"):
                key = f"{sched}_{pol}"
                _export_cell_trace(
                    cfg, sched, M, mb, s, alpha=0.0, ratios=ratios,
                    wave_size=W, act_policy=pol,
                    trace_path=os.path.join(trace_dir,
                                            f"{key}.trace.json"))
        rep.add("smoke/traces", trace_dir,
                "one Chrome trace-event JSON per cell")
    if json_path:
        import json
        out = {"config": {"model": cfg.name, "M": M, "micro_batch": mb,
                          "seq_len": s},
               "cells": cells}
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        rep.add("smoke/json", json_path, "feed to benchmarks/check_smoke.py")
    return cells


def run_wave(rep: Optional[Reporter] = None, smoke: bool = False) -> dict:
    """The wave-schedule interpolation datapoint: sweeping W from 1
    (horizontal) to M (vertical) trades checkpoint + inter-layer
    gradient traffic against parameter reloads — measured on the real
    engine, one compiled plan per W. Returns {W: measurement}."""
    rep = rep or Reporter()
    if smoke:
        cfg, M, mb, s, iters = get_config("gpt-tiny"), 4, 1, 64, 1
    else:
        cfg, M, mb, s, iters = get_config("gpt-100m"), 8, 1, 128, 2
    ratios = StorageRatios(0.0, 0.0, 0.0)
    rep.section(f"engine: wave schedule sweep ({cfg.name}, M={M}, "
                "x=(0,0,0))")
    out = {}
    for W in sorted({1, 2, M}):
        r = _measure(cfg, "wave", M, mb, s, alpha=0.0, ratios=ratios,
                     iters=iters, wave_size=W)
        out[W] = r
        name = {1: "horizontal", M: "vertical"}.get(W, "wave")
        rep.add(f"engine/wave_W{W}_s_per_iter", f"{r['s_per_iter']:.3f}",
                f"{name}; param {r['param_bytes_per_iter'] / 1e6:.1f} MB, "
                f"ckpt+ig {(r['ckpt_bytes_per_iter'] + r['inter_grad_bytes_per_iter']) / 1e6:.1f} MB/iter")
    ws = sorted(out)
    param = [out[w]["param_bytes_per_iter"] for w in ws]
    reread = [out[w]["ckpt_bytes_per_iter"]
              + out[w]["inter_grad_bytes_per_iter"] for w in ws]
    assert param == sorted(param, reverse=True), \
        f"param bytes must fall with W: {dict(zip(ws, param))}"
    assert reread == sorted(reread), \
        f"ckpt+inter-grad bytes must rise with W: {dict(zip(ws, reread))}"
    rep.add("engine/wave_interpolates", "yes",
            f"param {param[0] / param[-1]:.1f}x down, "
            f"ckpt+ig {reread[-1] / max(reread[0], 1):.1f}x up across W")
    return out


def run(rep: Optional[Reporter] = None) -> None:
    rep = rep or Reporter()
    rep.section("engine: measured vertical vs horizontal "
                "(gpt-100m, real 3-tier I/O)")
    cfg = get_config("gpt-100m")
    # I/O-heavy regime: params + opt states fully on "SSD", checkpoints in
    # CPU; 8 micro-batches so horizontal's 2M param reloads + (2M-1) grad
    # swaps dominate. (On this CPU container compute is much slower than
    # on an A100, so the paper's wall-clock gap is compressed — the
    # traffic ratio is the schedule-level effect.)
    M, mb, s = 8, 1, 128
    ratios = StorageRatios(1.0, 0.0, 0.0)
    res = {}
    for sched in ("horizontal", "vertical"):
        r = _measure(cfg, sched, M, mb, s, alpha=0.0, ratios=ratios)
        res[sched] = r
        rep.add(f"engine/{sched}_s_per_iter", f"{r['s_per_iter']:.3f}",
                f"traffic {r['traffic_bytes_per_iter'] / 1e9:.2f} GB/iter")
    sp = res["horizontal"]["s_per_iter"] / res["vertical"]["s_per_iter"]
    tr = res["horizontal"]["traffic_bytes_per_iter"] / \
        res["vertical"]["traffic_bytes_per_iter"]
    rep.add("engine/vertical_speedup", f"{sp:.2f}",
            f"wall-clock; traffic reduced {tr:.2f}x")
    rv = _measure(cfg, "vertical", M, mb, s, alpha=0.3, ratios=ratios)
    rep.add("engine/vertical_alpha0.3_s_per_iter",
            f"{rv['s_per_iter']:.3f}", "with delayed optimizer step")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="all",
                    choices=["all", "vertical", "horizontal", "wave"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, 1 iteration (CI)")
    ap.add_argument("--json", default="", help="with --smoke: run the "
                    "schedules-x-policy battery and dump per-cell "
                    "throughput for check_smoke.py")
    ap.add_argument("--trace-dir", default="", help="with --smoke "
                    "--json: export one Chrome trace-event JSON per "
                    "cell into this directory (CI artifact)")
    args = ap.parse_args(argv)
    rep = Reporter()
    if args.smoke and args.json:
        run_smoke(rep, json_path=args.json, trace_dir=args.trace_dir)
        return
    if args.schedule in ("all", "vertical", "horizontal"):
        run(rep)
    if args.schedule in ("all", "wave"):
        run_wave(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
