"""Benchmark the `repro.io` transfer engine.

Four measurements on the real filesystem of this container:

1. **Striping** — single-path vs multi-path chunked writes/reads of one
   large tensor (MLP-Offload's lever: once one path saturates, add
   paths). On a 2-core container the win comes from overlapping the
   per-path channel threads' memcpy+syscall work. Every config runs
   with a span tracer attached, so the report (and ``--json``) carries
   per-path ACHIEVED rates — bytes over channel-busy seconds, the same
   columns ``machine_from_snapshot`` ingests for the autotuner.
2. **Bandwidth simulation** — a token-bucket cap on ``cpu->ssd`` /
   ``ssd->cpu`` must reproduce the configured rate in wall-clock
   (the knob that makes perfmodel rooflines testable here).
3. **Perf-model plumbing** — ``machine_from_bandwidth`` +
   ``transfer_seconds`` predictions vs the measured capped transfers.
4. **Heterogeneous paths** — a 2-path device with PER-PATH token
   buckets at a 4:1 rate split, written/read under
   ``path_policy="static"`` (the ``i % P`` layout pays 2x the slow
   cap) vs ``"backlog"`` (placement drains toward sum-of-caps). The
   per-path byte split and achieved rates land in the report + JSON.
5. **Resilience overhead** (``--chaos``, opt-in) — the same streaming
   write/read workload with ``IOConfig.integrity`` + retries on, swept
   over :class:`repro.io.chaos.ChaosSpec` transient error rates: what
   the CRC sidecar costs at rate 0, and how throughput degrades as the
   engine's bounded retry absorbs injected EAGAIN faults (the data
   round-trips bitwise at every rate — that's asserted, not assumed).

    PYTHONPATH=src python benchmarks/bench_io.py [--size-mb 256]
        [--paths 1 2 4] [--chunk-kb 1024] [--cap-mbs 150] [--csv out.csv]
        [--chaos]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import Reporter, gb  # noqa: E402

from repro.core.perfmodel import machine_from_bandwidth, transfer_seconds
from repro.io import IOConfig, IOEngine
from repro.obs import Tracer
from repro.offload.stores import SSDStore, TrafficMeter


def _store(root: str, n_paths: int, chunk: int, bandwidth=None,
           path_bandwidth=None, path_policy: str = "static",
           tracer=None) -> SSDStore:
    paths = [os.path.join(root, f"nvme{i}") for i in range(n_paths)]
    eng = IOEngine(IOConfig(paths=paths, chunk_bytes=chunk,
                            bandwidth=bandwidth or {},
                            path_bandwidth=path_bandwidth,
                            path_policy=path_policy), tracer=tracer)
    return SSDStore(paths[0], TrafficMeter(), engine=eng)


def _per_path_rates(tracer: Tracer) -> dict:
    """{route: {path: {bytes, rate_bps}}} from the tracer's chunk spans
    — achieved rate while the single-thread path channel was busy."""
    out = {}
    for route, d in tracer.summary().get("routes", {}).items():
        pp = d.get("per_path") or {}
        if pp:
            out[route] = {p: {"bytes": v["bytes"],
                              "rate_bps": v["rate_bps"]}
                          for p, v in pp.items()}
    return out


def _fmt_rates(per_path: dict, route: str) -> str:
    pp = per_path.get(route, {})
    return "/".join(f"{pp[p]['rate_bps'] / 1e6:.0f}"
                    for p in sorted(pp, key=int)) or "-"


def _timed_write(ssd: SSDStore, name: str, arr: np.ndarray, reps: int = 3
                 ) -> float:
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        ssd.write(f"{name}:{r}", arr, "opt")
        best = min(best, time.perf_counter() - t0)
    return best


def _timed_read(ssd: SSDStore, name: str, nbytes: int, reps: int = 3
                ) -> float:
    out = np.empty(nbytes, np.uint8)
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        ssd.read(f"{name}:{r % reps}", "opt", out=out)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=256)
    ap.add_argument("--paths", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--chunk-kb", type=int, default=1024)
    ap.add_argument("--cap-mbs", type=float, default=150.0)
    ap.add_argument("--csv", default="")
    ap.add_argument("--json", default="", help="dump measured link rates "
                    "(bytes/s) for perfmodel.machine_from_bench, so "
                    "Algorithm 1 solves against THIS container's speeds")
    ap.add_argument("--chaos", action="store_true",
                    help="also sweep transient-fault rates with "
                         "integrity + retries on (resilience overhead)")
    args = ap.parse_args()

    rep = Reporter()
    nbytes = args.size_mb << 20
    chunk = args.chunk_kb << 10
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 255, nbytes, dtype=np.uint8)

    # ---- 1. striping ----
    rep.section(f"striped writes/reads, {args.size_mb} MB, "
                f"chunk {args.chunk_kb} KB")
    t_write, t_read, path_rates = {}, {}, {}
    with tempfile.TemporaryDirectory(prefix="bench_io_") as root:
        for P in args.paths:
            tr = Tracer()
            tr.enable()
            ssd = _store(os.path.join(root, f"P{P}"), P, chunk, tracer=tr)
            t_write[P] = _timed_write(ssd, "x", arr)
            t_read[P] = _timed_read(ssd, "x", nbytes)
            path_rates[P] = _per_path_rates(tr)
            rep.add(f"write_GBps_paths{P}", f"{nbytes / t_write[P] / 1e9:.2f}",
                    f"per-path MB/s {_fmt_rates(path_rates[P], 'cpu->ssd')}")
            rep.add(f"read_GBps_paths{P}", f"{nbytes / t_read[P] / 1e9:.2f}",
                    f"per-path MB/s {_fmt_rates(path_rates[P], 'ssd->cpu')}")
            ssd.close()
    base = args.paths[0]
    multi = [p for p in args.paths if p > 1]
    if base == 1 and multi:
        best = min(multi, key=lambda p: t_write[p])
        speedup = t_write[1] / t_write[best]
        rep.add("write_speedup_striped_vs_single", f"{speedup:.2f}",
                f"best={best}-path; target >= 1.3x")
        rd = t_read[1] / min(t_read[p] for p in multi)
        rep.add("read_speedup_striped_vs_single", f"{rd:.2f}")

    # ---- 2 + 3. bandwidth simulation vs perf model ----
    cap = args.cap_mbs * 1e6
    bw = {"cpu->ssd": cap, "ssd->cpu": 2 * cap}
    m = machine_from_bandwidth(bw)
    rep.section(f"token-bucket cap {args.cap_mbs:.0f} MB/s write, "
                f"{2 * args.cap_mbs:.0f} MB/s read")
    cap_bytes = min(nbytes, 64 << 20)
    sub = arr[:cap_bytes]
    with tempfile.TemporaryDirectory(prefix="bench_io_cap_") as root:
        ssd = _store(root, 1, chunk, bandwidth=bw)
        ssd.write("warm", sub[:4 << 20], "opt")       # settle fds/allocators
        tw = _timed_write(ssd, "capped", sub, reps=2)
        tr = _timed_read(ssd, "capped", cap_bytes, reps=2)
        ssd.close()
    for route, t_meas in (("cpu->ssd", tw), ("ssd->cpu", tr)):
        t_pred = transfer_seconds(m, route, cap_bytes)
        achieved = cap_bytes / t_meas
        rep.add(f"sim_{route.replace('->', '_to_')}_MBps",
                f"{achieved / 1e6:.1f}",
                f"configured {bw[route] / 1e6:.0f}")
        rep.add(f"sim_{route.replace('->', '_to_')}_vs_model",
                f"{t_meas / t_pred:.3f}",
                "measured/predicted seconds; target within +-20%")

    # ---- 4. heterogeneous paths: static i%P vs backlog placement ----
    hcaps = (args.cap_mbs * 1e6, args.cap_mbs / 4 * 1e6)
    rep.section(f"heterogeneous 2-path device, per-path caps "
                f"{hcaps[0] / 1e6:.0f}/{hcaps[1] / 1e6:.0f} MB/s (4:1)")
    het_bytes = min(nbytes, 32 << 20)
    hsub = arr[:het_bytes]
    hetero = {}
    for policy in ("static", "backlog"):
        with tempfile.TemporaryDirectory(prefix="bench_io_het_") as root:
            tr = Tracer()
            tr.enable()
            ssd = _store(root, 2, chunk, path_bandwidth=hcaps,
                         path_policy=policy, tracer=tr)
            htw = _timed_write(ssd, "het", hsub, reps=2)
            htr = _timed_read(ssd, "het", het_bytes, reps=2)
            ssd.close()
        pp = _per_path_rates(tr)
        hetero[policy] = {"write_s": htw, "read_s": htr,
                          "write_bps": het_bytes / htw,
                          "read_bps": het_bytes / htr,
                          "per_path": pp}
        rep.add(f"hetero_{policy}_write_MBps",
                f"{het_bytes / htw / 1e6:.1f}",
                f"per-path MB/s {_fmt_rates(pp, 'cpu->ssd')}")
        rep.add(f"hetero_{policy}_read_MBps",
                f"{het_bytes / htr / 1e6:.1f}",
                f"per-path MB/s {_fmt_rates(pp, 'ssd->cpu')}")
    rep.add("hetero_backlog_vs_static_write",
            f"{hetero['static']['write_s'] / hetero['backlog']['write_s']:.2f}",
            "x; static pays 2x the slow cap, backlog drains to sum-of-caps")

    # ---- 5. resilience overhead: integrity + retry under chaos ----
    chaos_cells = {}
    if args.chaos:
        from repro.io import ChaosSpec, install_chaos
        rates = (0.0, 0.01, 0.05)
        rep.section(f"resilience: integrity+retry streaming sweep, "
                    f"transient rates {rates}")
        ch_bytes = min(nbytes, 32 << 20)
        csub = arr[:ch_bytes]
        for rate in rates:
            with tempfile.TemporaryDirectory(prefix="bench_io_ch_") as root:
                paths = [os.path.join(root, f"nvme{i}") for i in range(2)]
                eng = IOEngine(IOConfig(paths=paths, chunk_bytes=chunk,
                                        retries=5, integrity=True))
                ssd = SSDStore(paths[0], TrafficMeter(), engine=eng)
                files = install_chaos(
                    ssd, ChaosSpec(error_rate=rate, seed=17))
                t0 = time.perf_counter()
                ssd.write("res", csub, "opt")
                back = ssd.read("res", "opt")
                dt = time.perf_counter() - t0
                assert np.array_equal(back, csub), \
                    f"round trip diverged at rate {rate}"
                s = eng.metrics_snapshot()
                chaos_cells[rate] = {
                    "round_trip_bps": 2 * ch_bytes / dt,
                    "injected": files.injected["transient"],
                    "chunk_retries": s["chunk_retries"],
                }
                ssd.close()
            c = chaos_cells[rate]
            rep.add(f"chaos_rate{rate}_MBps",
                    f"{c['round_trip_bps'] / 1e6:.1f}",
                    f"write+read round trip, {c['injected']} injected, "
                    f"{c['chunk_retries']} retries, bitwise OK")

    rep.section("summary")
    rep.add("bytes_benchmarked", gb(nbytes), "GB per striping config")
    if args.csv:
        rep.dump_csv(args.csv)
    if args.json:
        import json
        results = {
            "size_bytes": nbytes,
            "chunk_bytes": chunk,
            "paths": {str(P): {"write_bps": nbytes / t_write[P],
                               "read_bps": nbytes / t_read[P],
                               "per_path": path_rates[P]}
                      for P in args.paths},
            "hetero": {"path_bandwidth": list(hcaps),
                       "size_bytes": het_bytes, **hetero},
        }
        if chaos_cells:
            results["chaos"] = {str(r): c for r, c in chaos_cells.items()}
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        rep.add("json", args.json,
                "feed to repro.core.perfmodel.machine_from_bench")


if __name__ == "__main__":
    main()
