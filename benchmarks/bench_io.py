"""Benchmark the `repro.io` transfer engine.

Three measurements on the real filesystem of this container:

1. **Striping** — single-path vs multi-path chunked writes/reads of one
   large tensor (MLP-Offload's lever: once one path saturates, add
   paths). On a 2-core container the win comes from overlapping the
   per-path channel threads' memcpy+syscall work.
2. **Bandwidth simulation** — a token-bucket cap on ``cpu->ssd`` /
   ``ssd->cpu`` must reproduce the configured rate in wall-clock
   (the knob that makes perfmodel rooflines testable here).
3. **Perf-model plumbing** — ``machine_from_bandwidth`` +
   ``transfer_seconds`` predictions vs the measured capped transfers.

    PYTHONPATH=src python benchmarks/bench_io.py [--size-mb 256]
        [--paths 1 2 4] [--chunk-kb 1024] [--cap-mbs 150] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import Reporter, gb  # noqa: E402

from repro.core.perfmodel import machine_from_bandwidth, transfer_seconds
from repro.io import IOConfig, IOEngine
from repro.offload.stores import SSDStore, TrafficMeter


def _store(root: str, n_paths: int, chunk: int, bandwidth=None) -> SSDStore:
    paths = [os.path.join(root, f"nvme{i}") for i in range(n_paths)]
    eng = IOEngine(IOConfig(paths=paths, chunk_bytes=chunk,
                            bandwidth=bandwidth or {}))
    return SSDStore(paths[0], TrafficMeter(), engine=eng)


def _timed_write(ssd: SSDStore, name: str, arr: np.ndarray, reps: int = 3
                 ) -> float:
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        ssd.write(f"{name}:{r}", arr, "opt")
        best = min(best, time.perf_counter() - t0)
    return best


def _timed_read(ssd: SSDStore, name: str, nbytes: int, reps: int = 3
                ) -> float:
    out = np.empty(nbytes, np.uint8)
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        ssd.read(f"{name}:{r % reps}", "opt", out=out)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=256)
    ap.add_argument("--paths", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--chunk-kb", type=int, default=1024)
    ap.add_argument("--cap-mbs", type=float, default=150.0)
    ap.add_argument("--csv", default="")
    ap.add_argument("--json", default="", help="dump measured link rates "
                    "(bytes/s) for perfmodel.machine_from_bench, so "
                    "Algorithm 1 solves against THIS container's speeds")
    args = ap.parse_args()

    rep = Reporter()
    nbytes = args.size_mb << 20
    chunk = args.chunk_kb << 10
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 255, nbytes, dtype=np.uint8)

    # ---- 1. striping ----
    rep.section(f"striped writes/reads, {args.size_mb} MB, "
                f"chunk {args.chunk_kb} KB")
    t_write, t_read = {}, {}
    with tempfile.TemporaryDirectory(prefix="bench_io_") as root:
        for P in args.paths:
            ssd = _store(os.path.join(root, f"P{P}"), P, chunk)
            t_write[P] = _timed_write(ssd, "x", arr)
            t_read[P] = _timed_read(ssd, "x", nbytes)
            rep.add(f"write_GBps_paths{P}", f"{nbytes / t_write[P] / 1e9:.2f}")
            rep.add(f"read_GBps_paths{P}", f"{nbytes / t_read[P] / 1e9:.2f}")
            ssd.close()
    base = args.paths[0]
    multi = [p for p in args.paths if p > 1]
    if base == 1 and multi:
        best = min(multi, key=lambda p: t_write[p])
        speedup = t_write[1] / t_write[best]
        rep.add("write_speedup_striped_vs_single", f"{speedup:.2f}",
                f"best={best}-path; target >= 1.3x")
        rd = t_read[1] / min(t_read[p] for p in multi)
        rep.add("read_speedup_striped_vs_single", f"{rd:.2f}")

    # ---- 2 + 3. bandwidth simulation vs perf model ----
    cap = args.cap_mbs * 1e6
    bw = {"cpu->ssd": cap, "ssd->cpu": 2 * cap}
    m = machine_from_bandwidth(bw)
    rep.section(f"token-bucket cap {args.cap_mbs:.0f} MB/s write, "
                f"{2 * args.cap_mbs:.0f} MB/s read")
    cap_bytes = min(nbytes, 64 << 20)
    sub = arr[:cap_bytes]
    with tempfile.TemporaryDirectory(prefix="bench_io_cap_") as root:
        ssd = _store(root, 1, chunk, bandwidth=bw)
        ssd.write("warm", sub[:4 << 20], "opt")       # settle fds/allocators
        tw = _timed_write(ssd, "capped", sub, reps=2)
        tr = _timed_read(ssd, "capped", cap_bytes, reps=2)
        ssd.close()
    for route, t_meas in (("cpu->ssd", tw), ("ssd->cpu", tr)):
        t_pred = transfer_seconds(m, route, cap_bytes)
        achieved = cap_bytes / t_meas
        rep.add(f"sim_{route.replace('->', '_to_')}_MBps",
                f"{achieved / 1e6:.1f}",
                f"configured {bw[route] / 1e6:.0f}")
        rep.add(f"sim_{route.replace('->', '_to_')}_vs_model",
                f"{t_meas / t_pred:.3f}",
                "measured/predicted seconds; target within +-20%")

    rep.section("summary")
    rep.add("bytes_benchmarked", gb(nbytes), "GB per striping config")
    if args.csv:
        rep.dump_csv(args.csv)
    if args.json:
        import json
        results = {
            "size_bytes": nbytes,
            "chunk_bytes": chunk,
            "paths": {str(P): {"write_bps": nbytes / t_write[P],
                               "read_bps": nbytes / t_read[P]}
                      for P in args.paths},
        }
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        rep.add("json", args.json,
                "feed to repro.core.perfmodel.machine_from_bench")


if __name__ == "__main__":
    main()
