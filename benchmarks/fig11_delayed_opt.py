"""Fig. 11 — benefit of the delayed optimizer step (α > 0): the delayed
curve reaches the saturated throughput at a SMALLER batch size; both
curves converge to the same saturated throughput.

Model part: GPT-65B on the A100 machine, throughput vs n for α=0 vs the
per-n best α (Algorithm 1's inner argmax).
Measured part: the real offload engine on gpt-tiny, wall-clock per
iteration with α=0 vs α=0.3 (the α fraction of CPU-Adam + state I/O
moves into the next forward, shrinking the backward critical path).
"""
from __future__ import annotations

import tempfile
import time
from typing import Optional

import jax

from benchmarks.common import A100_CLOUD, Reporter
from repro.configs import get_config
from repro.core.lp_search import solve_config
from repro.core.perfmodel import StorageRatios, Workload
from repro.data import SyntheticLM
from repro.offload import OffloadConfig, OffloadEngine

ALPHAS = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]


def run(rep: Optional[Reporter] = None, seq: int = 2048) -> None:
    rep = rep or Reporter()
    rep.section("fig11: delayed optimizer step (GPT-65B, A100 model)")
    cfg = get_config("gpt-65b")
    w = Workload.from_config(cfg, micro_batch=2, seq_len=seq)

    sat_plain, sat_delay = 0.0, 0.0
    n_sat_plain = n_sat_delay = None
    tp_prev = {}
    for n in (2, 4, 8, 12, 16, 20, 24, 32, 48, 64):
        s0 = solve_config(A100_CLOUD, w, n, 0.0)
        best = min((solve_config(A100_CLOUD, w, n, a) for a in ALPHAS),
                   key=lambda s: s.iteration_time if s else float("inf"))
        tp0 = n * w.tokens_per_mb / s0.iteration_time
        tpb = n * w.tokens_per_mb / best.iteration_time
        rep.add(f"fig11/tp_n{n}", f"{tp0:.0f}->{tpb:.0f}",
                f"alpha=0 -> best-alpha tokens/s ({tpb / tp0:.3f}x)")
        sat_plain, sat_delay = max(sat_plain, tp0), max(sat_delay, tpb)
        if n_sat_plain is None and tp_prev.get("p") and \
                tp0 < 1.01 * tp_prev["p"]:
            n_sat_plain = n
        if n_sat_delay is None and tp_prev.get("d") and \
                tpb < 1.01 * tp_prev["d"]:
            n_sat_delay = n
        tp_prev = {"p": tp0, "d": tpb}
    rep.add("fig11/saturated_ratio", f"{sat_delay / sat_plain:.3f}",
            "same saturated throughput (paper: curves converge)")
    if n_sat_plain and n_sat_delay:
        rep.add("fig11/saturation_batch", f"{n_sat_delay}<={n_sat_plain}",
                "delayed saturates at smaller-or-equal batch")

    # ---- measured on the engine ----
    rep.section("fig11-measured: engine wall-clock, alpha 0 vs 0.3 "
                "(gpt-tiny, opt states 100% on SSD)")
    tcfg = get_config("gpt-tiny")
    M, mb, s = 4, 2, 64
    for alpha in (0.0, 0.3):
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(tcfg, OffloadConfig(
                schedule="vertical", num_microbatches=M, micro_batch=mb,
                seq_len=s, alpha=alpha,
                ratios=StorageRatios(1.0, 1.0, 0.0)),
                jax.random.PRNGKey(0), d)
            data = SyntheticLM(tcfg.vocab_size, seed=0)
            eng.train_step(data.batch(M * mb, s))  # warm-up / compile
            t0 = time.perf_counter()
            for _ in range(3):
                eng.train_step(data.batch(M * mb, s))
            eng.finish()
            dt = (time.perf_counter() - t0) / 3
            eng.close()
        rep.add(f"fig11/engine_s_per_iter_alpha{alpha}", f"{dt:.3f}",
                "wall-clock s/iter (backward no longer waits on full "
                "opt I/O when alpha>0)")


if __name__ == "__main__":
    run()
