"""Fig. 12 — 100% SSD offloading vs the LP-optimal config: throughput
rises more slowly but converges to a SIMILAR saturated level, proving
the gain comes from vertical scheduling itself, not CPU-memory caching.

Also reproduces the §6.4 "time credit" argument: per added micro-batch,
extra compute time vs extra checkpoint-I/O time (paper GPT-65B: 16.4 s
vs 1.1 s).
"""
from __future__ import annotations

from typing import Optional

from benchmarks.common import A100_CLOUD, Reporter
from repro.configs import get_config
from repro.core.lp_search import find_optimal_config, solve_config
from repro.core.perfmodel import StorageRatios, Workload, \
    iteration_time_vertical

ALPHAS = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]


def run(rep: Optional[Reporter] = None, seq: int = 2048) -> None:
    rep = rep or Reporter()
    rep.section("fig12: 100% SSD vs LP-optimal (GPT-65B, A100 model)")
    cfg = get_config("gpt-65b")
    w = Workload.from_config(cfg, micro_batch=2, seq_len=seq)
    x_ssd = StorageRatios(0.0, 0.0, 0.0)

    sat_opt, sat_ssd = 0.0, 0.0
    for n in (4, 8, 16, 24, 32, 48, 64, 96):
        best = min((solve_config(A100_CLOUD, w, n, a) for a in ALPHAS),
                   key=lambda s: s.iteration_time if s else float("inf"))
        tp_opt = n * w.tokens_per_mb / best.iteration_time
        t_ssd = min(iteration_time_vertical(w, A100_CLOUD, n, a, x_ssd)
                    for a in ALPHAS)
        tp_ssd = n * w.tokens_per_mb / t_ssd
        rep.add(f"fig12/tp_n{n}", f"{tp_ssd:.0f} vs {tp_opt:.0f}",
                f"100%-SSD vs LP-optimal tokens/s "
                f"({100 * tp_ssd / tp_opt:.0f}%)")
        sat_opt, sat_ssd = max(sat_opt, tp_opt), max(sat_ssd, tp_ssd)
    rep.add("fig12/saturated_ssd_vs_opt", f"{sat_ssd / sat_opt:.3f}",
            "paper: similar saturated throughput even at 100% SSD")

    # time-credit argument (§6.4): at the LP-optimal config checkpoints
    # are largely CPU-cached, so the added I/O per micro-batch is mostly
    # PCIe (the paper's 1.1 s figure); the SSD part covers the tail.
    res = find_optimal_config(A100_CLOUD, w, alphas=ALPHAS, max_n=128)
    xc = res.x.ckpt if res else 0.0
    t_comp_mb = 4 * w.flops_per_mb / A100_CLOUD.gpu_flops
    t_pcie = 3 * w.cs / A100_CLOUD.pcie_bw          # write + 2 reads
    t_ssd = (1 - xc) * (2 * w.cs / A100_CLOUD.ssd_read_bw
                        + w.cs / A100_CLOUD.ssd_write_bw)
    t_io_mb = max(t_pcie, t_ssd)
    rep.add("fig12/credit_compute_s", f"{t_comp_mb:.1f}",
            "fwd+bwd compute per added micro-batch (paper: 16.4 s)")
    rep.add("fig12/credit_io_s", f"{t_io_mb:.1f}",
            f"added ckpt I/O per micro-batch at x_ckpt={xc:.2f} "
            "(paper: 1.1 s)")
    rep.add("fig12/credit_ratio", f"{t_comp_mb / t_io_mb:.1f}",
            ">1 => each micro-batch accrues overlap credit")


if __name__ == "__main__":
    run()
